//! Paper-figure regeneration harness (DESIGN.md §5 experiment index).
//!
//! Every table/figure in the paper's evaluation has a function here that
//! produces the same rows/series; the CLI (`eonsim figures`) and the
//! bench harness print them. Absolute numbers differ from the paper's
//! testbed (our ground truth is the simulated TPUv6e baseline of
//! [`crate::tpuv6e`]); the *shape* — error magnitudes, who wins, by what
//! factor — is the reproduction target.

use crate::champsim::{ChampCache, ChampPolicy};
use crate::config::{presets, CachePolicyKind, OnchipPolicy, SimConfig};
use crate::engine::Simulator;
use crate::mem::Cache;
use crate::parallel::parallel_map;
use crate::tpuv6e;
use crate::trace::{AddressMap, TraceGenerator};

/// One point of Fig. 3a/3b: simulated vs measured execution time.
#[derive(Debug, Clone, Copy)]
pub struct ValidationPoint {
    /// Swept parameter (number of tables for 3a, batch size for 3b).
    pub x: usize,
    pub eonsim_secs: f64,
    pub tpuv6e_secs: f64,
}

impl ValidationPoint {
    pub fn err_pct(&self) -> f64 {
        (self.eonsim_secs - self.tpuv6e_secs).abs() / self.tpuv6e_secs * 100.0
    }
}

/// Mean |error| over a series.
pub fn mean_err_pct(points: &[ValidationPoint]) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    points.iter().map(|p| p.err_pct()).sum::<f64>() / points.len() as f64
}

pub fn max_err_pct(points: &[ValidationPoint]) -> f64 {
    points.iter().map(|p| p.err_pct()).fold(0.0, f64::max)
}

/// Baseline validation config: Table I hardware + DLRM-RMC2-small, SPM
/// policy (TPUv6e's staging-buffer behaviour), one batch per point.
pub fn validation_config(batch_size: usize, num_tables: usize) -> SimConfig {
    let mut cfg = presets::tpuv6e_dlrm_small();
    cfg.workload.batch_size = batch_size;
    cfg.workload.num_batches = 1;
    cfg.workload.embedding.num_tables = num_tables;
    cfg.hardware.mem.policy = OnchipPolicy::Spm;
    cfg
}

/// Fig. 3a: execution time, EONSim vs TPUv6e, varying the number of
/// embedding tables (paper: 30–60, avg err ≈ 2 %).
pub fn fig3a(tables: &[usize], batch_size: usize) -> anyhow::Result<Vec<ValidationPoint>> {
    parallel_map(tables, |&t| {
        let cfg = validation_config(batch_size, t);
        let report = Simulator::new(cfg.clone()).run()?;
        let measured = tpuv6e::measure(&cfg)?;
        Ok(ValidationPoint {
            x: t,
            eonsim_secs: report.exec_time_secs(),
            tpuv6e_secs: measured.exec_secs,
        })
    })
}

/// Fig. 3b: execution time, EONSim vs TPUv6e, varying batch size
/// (paper: 32–2048 step 32, avg err ≈ 1.4 %, max 4 %).
pub fn fig3b(batch_sizes: &[usize], num_tables: usize) -> anyhow::Result<Vec<ValidationPoint>> {
    parallel_map(batch_sizes, |&b| {
        let cfg = validation_config(b, num_tables);
        let report = Simulator::new(cfg.clone()).run()?;
        let measured = tpuv6e::measure(&cfg)?;
        Ok(ValidationPoint {
            x: b,
            eonsim_secs: report.exec_time_secs(),
            tpuv6e_secs: measured.exec_secs,
        })
    })
}

/// One Fig. 3c row: on-/off-chip access counts, EONSim normalized to the
/// TPUv6e estimate.
#[derive(Debug, Clone, Copy)]
pub struct AccessPoint {
    pub batch: usize,
    pub onchip_ratio_vs_tpu: f64,
    pub offchip_ratio_vs_tpu: f64,
}

impl AccessPoint {
    pub fn onchip_err_pct(&self) -> f64 {
        (self.onchip_ratio_vs_tpu - 1.0).abs() * 100.0
    }

    pub fn offchip_err_pct(&self) -> f64 {
        (self.offchip_ratio_vs_tpu - 1.0).abs() * 100.0
    }
}

/// Fig. 3c: memory access counts normalized to TPUv6e (paper: 2.2 % /
/// 2.8 % average error on-/off-chip).
pub fn fig3c(batch_sizes: &[usize], num_tables: usize) -> anyhow::Result<Vec<AccessPoint>> {
    parallel_map(batch_sizes, |&b| {
        let cfg = validation_config(b, num_tables);
        let report = Simulator::new(cfg.clone()).run()?;
        let measured = tpuv6e::measure(&cfg)?;
        let m = report.total_mem();
        Ok(AccessPoint {
            batch: b,
            onchip_ratio_vs_tpu: m.onchip_total() as f64 / measured.onchip_accesses as f64,
            offchip_ratio_vs_tpu: m.offchip_total() as f64 / measured.offchip_accesses as f64,
        })
    })
}

/// One Fig. 4a row: hit/miss counts, EONSim's cache vs the independent
/// ChampSim-style implementation (must be identical).
#[derive(Debug, Clone)]
pub struct ChampComparison {
    pub policy: &'static str,
    pub dataset: &'static str,
    pub eonsim_hits: u64,
    pub eonsim_misses: u64,
    pub champsim_hits: u64,
    pub champsim_misses: u64,
}

impl ChampComparison {
    pub fn identical(&self) -> bool {
        self.eonsim_hits == self.champsim_hits && self.eonsim_misses == self.champsim_misses
    }
}

/// Fig. 4a: replay the same embedding line trace through both cache
/// implementations under LRU and SRRIP (paper: identical counts).
pub fn fig4a(
    onchip_bytes: u64,
    batches: usize,
    batch_size: usize,
) -> anyhow::Result<Vec<ChampComparison>> {
    let mut out = Vec::new();
    for dataset in presets::ReuseDataset::all() {
        for (kind, champ, name) in [
            (CachePolicyKind::Lru, ChampPolicy::Lru, "lru"),
            (CachePolicyKind::Srrip, ChampPolicy::Srrip, "srrip"),
        ] {
            let mut cfg = validation_config(batch_size, 60);
            cfg.workload.trace = dataset.trace_config(cfg.workload.trace.seed);
            let emb = &cfg.workload.embedding;
            let gran = cfg.hardware.mem.access_granularity;
            let assoc = cfg.hardware.mem.cache_assoc;
            let addr_map = AddressMap::new(emb, gran);
            let mut gen = TraceGenerator::new(&cfg.workload)?;
            let mut eon = Cache::new(onchip_bytes, gran, assoc, kind);
            let mut champ_cache = ChampCache::new(onchip_bytes, gran, assoc, champ);
            for _ in 0..batches {
                for l in &gen.next_batch().lookups {
                    for line in addr_map.lines(l.table, l.row) {
                        eon.access(line);
                        champ_cache.access(line);
                    }
                }
            }
            out.push(ChampComparison {
                policy: name,
                dataset: dataset.name(),
                eonsim_hits: eon.hits(),
                eonsim_misses: eon.misses(),
                champsim_hits: champ_cache.hits(),
                champsim_misses: champ_cache.misses(),
            });
        }
    }
    Ok(out)
}

/// One Fig. 4b/4c row: a policy's result on one reuse dataset.
#[derive(Debug, Clone)]
pub struct PolicyPoint {
    pub dataset: &'static str,
    pub policy: &'static str,
    pub cycles: u64,
    /// Speedup vs the SPM baseline on the same dataset (Fig. 4b).
    pub speedup_vs_spm: f64,
    /// On-chip memory access ratio (Fig. 4c).
    pub onchip_ratio: f64,
}

/// Figs. 4b + 4c: SPM / LRU / SRRIP / Profiling across the reuse
/// datasets. Paper shape: LRU+SRRIP >= 1.5x on High/Mid, limited on Low;
/// Profiling best everywhere; SRRIP's on-chip ratio ≈ +3 % over LRU.
pub fn fig4bc(
    batch_size: usize,
    num_batches: usize,
    onchip_bytes: u64,
) -> anyhow::Result<Vec<PolicyPoint>> {
    let policies: [(&'static str, OnchipPolicy); 4] = [
        ("spm", OnchipPolicy::Spm),
        ("lru", OnchipPolicy::Cache(CachePolicyKind::Lru)),
        ("srrip", OnchipPolicy::Cache(CachePolicyKind::Srrip)),
        ("profiling", OnchipPolicy::Pinning),
    ];
    let cells: Vec<(presets::ReuseDataset, (&'static str, OnchipPolicy))> = presets::ReuseDataset::all()
        .into_iter()
        .flat_map(|d| policies.into_iter().map(move |p| (d, p)))
        .collect();
    let mut out = parallel_map(&cells, |&(dataset, (name, policy))| {
        let mut cfg = validation_config(batch_size, 60);
        cfg.workload.num_batches = num_batches;
        cfg.workload.trace = dataset.trace_config(cfg.workload.trace.seed);
        cfg.hardware.mem.policy = policy;
        cfg.hardware.mem.onchip_bytes = onchip_bytes;
        let report = Simulator::new(cfg).run()?;
        Ok(PolicyPoint {
            dataset: dataset.name(),
            policy: name,
            cycles: report.total_cycles(),
            speedup_vs_spm: 0.0, // filled below from the SPM row
            onchip_ratio: report.total_mem().onchip_ratio(),
        })
    })?;
    for dataset in presets::ReuseDataset::all() {
        let spm_cycles = out
            .iter()
            .find(|p| p.dataset == dataset.name() && p.policy == "spm")
            .map(|p| p.cycles)
            .unwrap_or(0);
        for p in out.iter_mut().filter(|p| p.dataset == dataset.name()) {
            p.speedup_vs_spm = spm_cycles as f64 / p.cycles as f64;
        }
    }
    Ok(out)
}

/// Default sampled sweeps (full paper sweeps via `eonsim figures --full`).
pub const FIG3A_TABLES: &[usize] = &[30, 35, 40, 45, 50, 55, 60];
pub const FIG3B_BATCHES_SAMPLED: &[usize] = &[32, 64, 128, 256, 512, 1024, 2048];

/// The full 32..=2048-step-32 batch sweep of the paper.
pub fn fig3b_full_sweep() -> Vec<usize> {
    (1..=64).map(|i| i * 32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3b_full_sweep_matches_paper_range() {
        let s = fig3b_full_sweep();
        assert_eq!(s.len(), 64);
        assert_eq!(s[0], 32);
        assert_eq!(*s.last().unwrap(), 2048);
    }

    #[test]
    fn validation_point_error() {
        let p = ValidationPoint { x: 0, eonsim_secs: 1.02, tpuv6e_secs: 1.0 };
        assert!((p.err_pct() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn small_fig3a_runs() {
        // tiny smoke: 2 points at small batch
        let pts = fig3a(&[4, 8], 8).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[1].eonsim_secs > pts[0].eonsim_secs);
    }
}

//! Multi-device table-sharded embedding simulation.
//!
//! Production DLRM serving shards its embedding tables across many NPU
//! devices (TensorDIMM-style placement): each device owns a shard in its
//! *own* memory system (local buffers + controller + HBM), gathers and
//! pools its share of every batch, and an all-to-all exchange
//! redistributes the pooled vectors to each sample's home device before
//! feature interaction. This module models exactly that:
//!
//! * [`TablePartitioner`] splits a [`BatchTrace`] across `N` devices —
//!   table-wise (whole tables round-robin) or row-hashed (rows scattered
//!   by hash for load balance under per-table skew);
//! * [`ShardedEmbeddingSim`] drives one persistent
//!   [`EmbeddingSim`] per device over its sub-trace, so cross-batch
//!   on-chip reuse is preserved per shard;
//! * an interconnect model charges the embedding-exchange phase from the
//!   busiest device's send volume over a configurable link bandwidth
//!   plus a fixed hop latency.
//!
//! With one device (the preset default) the partitioner is the identity,
//! the exchange is free, and every result is bit-identical to the
//! classic single-NPU path.

use crate::config::{ShardStrategy, SimConfig};
use crate::engine::embedding::EmbeddingSim;
use crate::mem::policy::pinning::PinSet;
use crate::stats::{DeviceCounters, MemCounts, OpCounts};
use crate::testutil::mix64;
use crate::trace::{BatchTrace, Lookup};

/// One device's share of a batch: its lookups (in original issue order)
/// and the number of distinct bags it contributes pooled vectors to.
#[derive(Debug, Clone)]
pub struct DeviceTrace {
    pub trace: BatchTrace,
    /// Distinct `(sample, table)` bags this device holds (partial or
    /// complete) pooled results for — the unit of exchange traffic.
    pub bags: u64,
}

/// Splits batch traces across devices according to a [`ShardStrategy`].
#[derive(Debug, Clone)]
pub struct TablePartitioner {
    devices: usize,
    strategy: ShardStrategy,
    /// Lookups per sample (tables * pool), for bag identification.
    lookups_per_sample: usize,
}

impl TablePartitioner {
    pub fn new(devices: usize, strategy: ShardStrategy, lookups_per_sample: usize) -> Self {
        TablePartitioner {
            devices: devices.max(1),
            strategy,
            lookups_per_sample: lookups_per_sample.max(1),
        }
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Which device serves one lookup.
    #[inline]
    pub fn device_of(&self, lookup: &Lookup) -> usize {
        match self.strategy {
            ShardStrategy::TableWise => lookup.table as usize % self.devices,
            ShardStrategy::RowHashed => {
                (mix64(((lookup.table as u64) << 48) ^ lookup.row) % self.devices as u64) as usize
            }
        }
    }

    /// Split one batch into per-device sub-traces, preserving the
    /// original issue order within each device. Every lookup lands on
    /// exactly one device, so all per-lookup counters conserve.
    pub fn split(&self, trace: &BatchTrace) -> Vec<DeviceTrace> {
        let mut out: Vec<DeviceTrace> = (0..self.devices)
            .map(|_| DeviceTrace {
                trace: BatchTrace {
                    batch_index: trace.batch_index,
                    lookups: Vec::with_capacity(trace.lookups.len() / self.devices + 1),
                },
                bags: 0,
            })
            .collect();
        // lookups are sample-major then table then pooling slot, so one
        // bag's lookups are contiguous: a device contributes to a bag
        // iff its last-seen bag id changes
        let mut last_bag: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        for (i, l) in trace.lookups.iter().enumerate() {
            let d = self.device_of(l);
            let bag = (i / self.lookups_per_sample, l.table);
            if last_bag[d] != Some(bag) {
                last_bag[d] = Some(bag);
                out[d].bags += 1;
            }
            out[d].trace.lookups.push(*l);
        }
        out
    }
}

/// Result of one batch's sharded embedding stage.
#[derive(Debug, Clone)]
pub struct ShardedStageResult {
    /// Embedding-stage wall cycles: the slowest device's gather+pool.
    pub cycles: u64,
    /// All-to-all exchange cycles charged after pooling (0 on 1 device).
    pub exchange_cycles: u64,
    /// Memory counters summed over devices.
    pub mem: MemCounts,
    /// Operation counters summed over devices.
    pub ops: OpCounts,
    /// Per-device split of the same.
    pub per_device: Vec<DeviceCounters>,
}

/// Persistent multi-device embedding simulator: one [`EmbeddingSim`]
/// (local buffers, controller, DRAM state) per device plus the
/// partitioner and interconnect model.
pub struct ShardedEmbeddingSim {
    devices: Vec<EmbeddingSim>,
    partitioner: TablePartitioner,
    link_bytes_per_cycle: f64,
    hop_latency_cycles: u64,
    /// Bytes of one pooled embedding vector (dim * elem).
    vec_bytes: u64,
}

impl ShardedEmbeddingSim {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.sharding.devices.max(1);
        let emb = &cfg.workload.embedding;
        let devices = (0..n)
            .map(|d| {
                let mut sim = EmbeddingSim::new(cfg);
                // a device's sub-trace carries only its shard's lookups
                // per sample: exactly `owned_tables * pool` table-wise
                // (tables are assigned round-robin, so device d owns one
                // extra table when d < tables % n), ~`tables * pool / n`
                // row-hashed — align the per-core sample stride to that
                let owned_tables =
                    emb.num_tables / n + usize::from(d < emb.num_tables % n);
                let per_sample = match cfg.sharding.strategy {
                    ShardStrategy::TableWise => owned_tables * emb.pool,
                    ShardStrategy::RowHashed => emb.num_tables * emb.pool / n,
                };
                sim.set_lookups_per_sample(per_sample);
                sim
            })
            .collect();
        ShardedEmbeddingSim {
            devices,
            partitioner: TablePartitioner::new(
                n,
                cfg.sharding.strategy,
                emb.num_tables * emb.pool,
            ),
            link_bytes_per_cycle: cfg.sharding.link_bytes_per_cycle.max(f64::MIN_POSITIVE),
            hop_latency_cycles: cfg.sharding.hop_latency_cycles,
            vec_bytes: emb.vec_bytes(),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Install the profiling-derived pin set on every device (the
    /// profile is workload-global; each shard pins its hot vectors).
    pub fn set_pin_set(&mut self, pins: PinSet) {
        for dev in &mut self.devices {
            dev.set_pin_set(pins.clone());
        }
    }

    /// All-to-all cycles for per-device send volumes: the busiest
    /// device's outbound bytes over one link, plus a fixed hop latency.
    /// Each device keeps `1/N` of its pooled output local, so `N - 1` of
    /// `N` parts travel.
    fn exchange_cycles(&self, send_bytes: &[u64]) -> u64 {
        let max_bytes = send_bytes.iter().copied().max().unwrap_or(0);
        if max_bytes == 0 {
            return 0;
        }
        self.hop_latency_cycles + (max_bytes as f64 / self.link_bytes_per_cycle).ceil() as u64
    }

    /// Simulate one batch across all devices.
    pub fn simulate_batch(&mut self, trace: &BatchTrace) -> ShardedStageResult {
        let n = self.devices.len();
        if n == 1 {
            // single-device fast path: bit-identical to the classic
            // EmbeddingSim on the unsplit trace, exchange-free
            let r = self.devices[0].simulate_batch(trace);
            return ShardedStageResult {
                cycles: r.cycles,
                exchange_cycles: 0,
                mem: r.mem,
                ops: r.ops,
                per_device: vec![DeviceCounters {
                    device: 0,
                    cycles: r.cycles,
                    exchange_bytes: 0,
                    mem: r.mem,
                    ops: r.ops,
                }],
            };
        }

        let split = self.partitioner.split(trace);
        let mut mem = MemCounts::default();
        let mut ops = OpCounts::default();
        let mut per_device = Vec::with_capacity(n);
        let mut send_bytes = Vec::with_capacity(n);
        let mut wall = 0u64;
        for (device, (sim, part)) in self.devices.iter_mut().zip(&split).enumerate() {
            let r = sim.simulate_batch(&part.trace);
            wall = wall.max(r.cycles);
            mem.add(&r.mem);
            ops.add(&r.ops);
            // pooled output for `bags` bags; (n-1)/n of it is remote
            let bytes = part.bags * self.vec_bytes * (n as u64 - 1) / n as u64;
            send_bytes.push(bytes);
            per_device.push(DeviceCounters {
                device,
                cycles: r.cycles,
                exchange_bytes: bytes,
                mem: r.mem,
                ops: r.ops,
            });
        }
        ShardedStageResult {
            cycles: wall,
            exchange_cycles: self.exchange_cycles(&send_bytes),
            mem,
            ops,
            per_device,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, OnchipPolicy};
    use crate::trace::TraceGenerator;

    fn small_cfg(devices: usize, strategy: ShardStrategy) -> SimConfig {
        let mut cfg = presets::tpuv6e_dlrm_small();
        cfg.workload.batch_size = 32;
        cfg.workload.embedding.num_tables = 8;
        cfg.workload.embedding.rows_per_table = 20_000;
        cfg.workload.embedding.pool = 16;
        cfg.workload.trace.alpha = 1.1;
        cfg.hardware.mem.policy = OnchipPolicy::Spm;
        cfg.sharding.devices = devices;
        cfg.sharding.strategy = strategy;
        cfg
    }

    fn one_batch(cfg: &SimConfig) -> BatchTrace {
        TraceGenerator::new(&cfg.workload).unwrap().next_batch()
    }

    #[test]
    fn table_wise_assigns_whole_tables() {
        let p = TablePartitioner::new(4, ShardStrategy::TableWise, 128);
        for table in 0..16u32 {
            let d = p.device_of(&Lookup { table, row: 0 });
            assert_eq!(d, table as usize % 4);
            // rows never move a table-wise lookup
            assert_eq!(d, p.device_of(&Lookup { table, row: 12345 }));
        }
    }

    #[test]
    fn row_hashed_spreads_rows_of_one_table() {
        let p = TablePartitioner::new(4, ShardStrategy::RowHashed, 128);
        let mut seen = [false; 4];
        for row in 0..64 {
            seen[p.device_of(&Lookup { table: 0, row })] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 rows must touch all 4 devices");
    }

    #[test]
    fn split_conserves_and_preserves_order() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::RowHashed,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        // each sub-trace is a subsequence of the original
        for d in &split {
            let mut cursor = trace.lookups.iter();
            for l in &d.trace.lookups {
                assert!(cursor.any(|x| x == l), "order violated for {l:?}");
            }
        }
    }

    #[test]
    fn table_wise_bag_count_is_owned_tables_times_batch() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::TableWise,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        // 8 tables over 4 devices = 2 tables each; every (sample, table)
        // bag is complete on its owner
        for d in &split {
            assert_eq!(d.bags, 2 * cfg.workload.batch_size as u64);
        }
    }

    #[test]
    fn single_device_is_bit_identical_to_embedding_sim() {
        let cfg = small_cfg(1, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let mut plain = EmbeddingSim::new(&cfg);
        let mut sharded = ShardedEmbeddingSim::new(&cfg);
        let a = plain.simulate_batch(&trace);
        let b = sharded.simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.mem, b.mem);
        assert_eq!(b.exchange_cycles, 0);
        assert_eq!(b.per_device.len(), 1);
    }

    #[test]
    fn counters_conserve_across_devices_under_spm() {
        // SPM streams every line off-chip, so per-device sums must equal
        // the 1-device run exactly, for both strategies
        for strategy in [ShardStrategy::TableWise, ShardStrategy::RowHashed] {
            let cfg1 = small_cfg(1, strategy);
            let trace = one_batch(&cfg1);
            let one = ShardedEmbeddingSim::new(&cfg1).simulate_batch(&trace);
            let cfg4 = small_cfg(4, strategy);
            let mut sim4 = ShardedEmbeddingSim::new(&cfg4);
            let four = sim4.simulate_batch(&trace);
            assert_eq!(four.mem.offchip_reads, one.mem.offchip_reads, "{strategy:?}");
            assert_eq!(four.mem.hits, one.mem.hits, "{strategy:?}");
            assert_eq!(four.ops.lookups, one.ops.lookups, "{strategy:?}");
            let dev_sum: u64 = four.per_device.iter().map(|d| d.mem.offchip_reads).sum();
            assert_eq!(dev_sum, one.mem.offchip_reads, "{strategy:?}");
        }
    }

    #[test]
    fn sharding_is_deterministic() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let a = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        let b = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.exchange_cycles, b.exchange_cycles);
        assert_eq!(a.mem, b.mem);
    }

    #[test]
    fn more_devices_never_slow_the_embedding_stage() {
        let mut prev = u64::MAX;
        for devices in [1usize, 2, 4] {
            let cfg = small_cfg(devices, ShardStrategy::TableWise);
            let trace = one_batch(&cfg);
            let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
            assert!(
                r.cycles <= prev,
                "{devices} devices: {} cycles > previous {prev}",
                r.cycles
            );
            prev = r.cycles;
        }
    }

    #[test]
    fn exchange_positive_on_multi_device_and_scales_with_links() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert!(r.exchange_cycles > cfg.sharding.hop_latency_cycles);

        let mut fast = cfg.clone();
        fast.sharding.link_bytes_per_cycle *= 8.0;
        let rf = ShardedEmbeddingSim::new(&fast).simulate_batch(&trace);
        assert!(rf.exchange_cycles < r.exchange_cycles, "faster links must shrink exchange");
    }

    #[test]
    fn row_hashed_exchanges_more_than_table_wise() {
        // row-hashing leaves nearly every device with partials for
        // nearly every bag — the classic row-wise reduce cost
        let cfg_t = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg_t);
        let t = ShardedEmbeddingSim::new(&cfg_t).simulate_batch(&trace);
        let cfg_r = small_cfg(4, ShardStrategy::RowHashed);
        let r = ShardedEmbeddingSim::new(&cfg_r).simulate_batch(&trace);
        let sum = |x: &ShardedStageResult| -> u64 {
            x.per_device.iter().map(|d| d.exchange_bytes).sum()
        };
        assert!(sum(&r) > sum(&t), "row {} !> table {}", sum(&r), sum(&t));
    }
}

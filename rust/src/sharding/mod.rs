//! Multi-device sharded embedding simulation (skew-aware v2).
//!
//! Production DLRM serving shards its embedding tables across many NPU
//! devices (TensorDIMM-style placement): each device owns a shard in its
//! *own* memory system (local buffers + controller + HBM), gathers and
//! pools its share of every batch, and an all-to-all exchange
//! redistributes the pooled vectors to each sample's home device before
//! feature interaction. This module models exactly that:
//!
//! * [`TablePartitioner`] splits a [`BatchTrace`] across `N` devices —
//!   table-wise (whole tables round-robin), row-hashed (rows scattered
//!   by hash for load balance under per-table skew), or column-wise
//!   (every device gathers its `dim / N` slice of every lookup, so load
//!   balance is perfect and the exchange carries partial vectors);
//! * [`replicate::HotRowReplicator`] (installed via
//!   [`ShardedEmbeddingSim::set_replicas`]) pins the trace's top-K
//!   hottest rows on every device: lookups to them are rerouted to the
//!   sample's home device and served on-chip, costing no exchange and no
//!   off-chip read but pinning `K * vec_bytes` of each device's buffer;
//! * [`ShardedEmbeddingSim`] drives one persistent
//!   [`EmbeddingSim`] per device over its sub-trace, so cross-batch
//!   on-chip reuse is preserved per shard;
//! * an interconnect model charges the embedding-exchange phase from the
//!   busiest device's send volume over a configurable link bandwidth
//!   plus a fixed hop latency. Replica-served bags are produced at their
//!   home device and charge nothing;
//! * [`topology::Topology`] optionally splits the pod into nodes
//!   (`[topology] nodes > 1`): exchange bags whose home device shares
//!   the sender's node drain over the per-device intra links, the rest
//!   over each node's shared uplink — with per-node hot-row replication
//!   (one copy at each node's leader) and a node-aware
//!   [`topology::TablePlacement`] pass riding on top.
//!
//! With one device (the preset default) the partitioner is the identity,
//! the exchange is free, replication is inert, and every result is
//! bit-identical to the classic single-NPU path. With replication off
//! and the serial exchange (the defaults), results are bit-identical to
//! the original table-sharded model; with one node (the default) the
//! tiered accounting degenerates to exactly the flat model.
//!
//! The per-device and per-tier byte counters kept here (exchange bytes,
//! uplink `inter_bytes`) also feed the opt-in energy model
//! ([`crate::energy`]), which prices intra-node and uplink traffic at
//! different pJ/byte rates. Where this module sits in the overall
//! dataflow is mapped in `docs/ARCHITECTURE.md` at the repo root.

pub mod replicate;
pub mod topology;

use crate::config::{ShardStrategy, SimConfig};
use crate::engine::embedding::EmbeddingSim;
use crate::mem::policy::pinning::PinSet;
use crate::stats::{DeviceCounters, MemCounts, OpCounts};
use crate::testutil::mix64;
use crate::trace::{BatchTrace, Lookup};
use replicate::HotRowReplicator;
use topology::{TablePlacement, Topology};

/// One device's share of a batch: its lookups (in original issue order)
/// and the number of distinct bags it contributes pooled vectors to.
#[derive(Debug, Clone)]
pub struct DeviceTrace {
    pub trace: BatchTrace,
    /// Distinct `(sample, table)` bags this device holds (partial or
    /// complete) pooled results for — including replica-served bags.
    pub bags: u64,
    /// The subset of `bags` that must travel the all-to-all. Bag entries
    /// created only by replica-routed lookups live at the sample's home
    /// device already and are excluded. Equal to `bags` when no replica
    /// set is installed.
    pub exchange_bags: u64,
    /// The subset of `exchange_bags` whose home device is another
    /// device in the *same node* (intra-tier traffic). Bags consumed on
    /// this device itself stay local and appear in neither tier count.
    pub intra_bags: u64,
    /// The subset of `exchange_bags` whose home device is in *another
    /// node* (inter-tier traffic; always 0 on a flat topology).
    pub inter_bags: u64,
    /// The subset of `inter_bags` for which this device is its node's
    /// *first* contributor (in trace order). Summed over a node's
    /// devices this counts the node's **distinct** off-node bags — what
    /// the uplink carries when hierarchical reduction combines the
    /// node's row-hashed partials intra-node before shipping. Always
    /// `<= inter_bags`; equal when every off-node bag has one
    /// contributor per node (e.g. table-wise sharding).
    pub node_led_inter_bags: u64,
    /// Per-node replication only: replica-served bags produced at this
    /// (leader) device but consumed at another device of the same node,
    /// shipped whole over the intra-node links. 0 in per-device
    /// replication mode, where replicas live at the home device itself.
    pub replica_ship_bags: u64,
    /// Lookups routed here because their row is replicated on-device.
    pub replicated: u64,
}

/// Splits batch traces across devices according to a [`ShardStrategy`],
/// rerouting replicated hot rows to their sample's home device (or, in
/// per-node replication mode, to the home node's leader).
#[derive(Debug, Clone)]
pub struct TablePartitioner {
    devices: usize,
    strategy: ShardStrategy,
    /// Lookups per sample (tables * pool), for bag/home identification.
    lookups_per_sample: usize,
    replicas: HotRowReplicator,
    /// Node structure for tier accounting and per-node replica routing
    /// (flat by default — every pair of devices is same-node).
    topology: Topology,
    /// Per-node replication: replicated lookups route to the home
    /// node's *leader* device instead of the home device itself.
    replicate_per_node: bool,
    /// Node-aware table → device map (table-wise sharding only);
    /// `None` = the legacy `table % devices` round-robin.
    placement: Option<TablePlacement>,
}

impl TablePartitioner {
    pub fn new(devices: usize, strategy: ShardStrategy, lookups_per_sample: usize) -> Self {
        let devices = devices.max(1);
        TablePartitioner {
            devices,
            strategy,
            lookups_per_sample: lookups_per_sample.max(1),
            replicas: HotRowReplicator::empty(),
            topology: Topology::flat(devices, 1.0),
            replicate_per_node: false,
            placement: None,
        }
    }

    /// Install the hot-row replica set used to reroute lookups.
    pub fn set_replicas(&mut self, replicas: HotRowReplicator) {
        self.replicas = replicas;
    }

    /// Install the node structure used for tier accounting (and, with
    /// [`set_replicate_per_node`](Self::set_replicate_per_node), for
    /// leader routing). Must agree with this partitioner's device count.
    pub fn set_topology(&mut self, topology: Topology) {
        debug_assert!(topology.devices() >= self.devices, "topology too small");
        self.topology = topology;
    }

    /// Route replicated lookups to the home node's leader device (which
    /// holds the node's single replica copy) instead of the home device.
    pub fn set_replicate_per_node(&mut self, per_node: bool) {
        self.replicate_per_node = per_node;
    }

    /// Install an explicit table → device placement (table-wise
    /// sharding; other strategies never consult it).
    pub fn set_placement(&mut self, placement: TablePlacement) {
        self.placement = Some(placement);
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Which device owns one (non-replicated) lookup. Column-wise
    /// sharding has no single owner — every device gathers a dim-slice —
    /// so [`split`](Self::split) places such lookups on all devices and
    /// this returns 0 only as a nominal anchor.
    #[inline]
    pub fn device_of(&self, lookup: &Lookup) -> usize {
        match self.strategy {
            ShardStrategy::TableWise => match &self.placement {
                Some(p) => p.device_of(lookup.table),
                None => lookup.table as usize % self.devices,
            },
            ShardStrategy::RowHashed => {
                (mix64(((lookup.table as u64) << 48) ^ lookup.row) % self.devices as u64) as usize
            }
            ShardStrategy::ColumnWise => 0,
        }
    }

    /// Where a replicated lookup is served: its sample's home device
    /// (per-device replication — every device holds the replicas) or
    /// the home node's leader (per-node replication — one copy per
    /// node, shipped home over the intra-node links).
    #[inline]
    fn replica_target(&self, lookup_index: usize) -> usize {
        let home = self.home_of(lookup_index);
        if self.replicate_per_node {
            self.topology.leader_of(self.topology.node_of(home))
        } else {
            home
        }
    }

    /// The device a sample's pooled bags are consumed on (feature
    /// interaction + top-MLP): samples round-robin across devices.
    #[inline]
    fn home_of(&self, lookup_index: usize) -> usize {
        (lookup_index / self.lookups_per_sample) % self.devices
    }

    /// Split one batch into per-device sub-traces, preserving the
    /// original issue order within each device. Under table/row sharding
    /// every lookup lands on exactly one device; under column-wise every
    /// non-replicated lookup lands on every device (each gathers its
    /// dim-slice). Replicated lookups always land exactly once: at the
    /// sample's home device, or at the home node's leader in per-node
    /// replication mode.
    pub fn split(&self, trace: &BatchTrace) -> Vec<DeviceTrace> {
        let mut out = Vec::new();
        self.split_into(trace, &mut out);
        out
    }

    /// [`split`](Self::split) into a caller-owned buffer, reusing each
    /// device's `Vec<Lookup>` allocation across batches (the per-batch
    /// per-device allocations were a measurable share of sharded-run
    /// host time; the sharded engine feeds the same buffer every batch).
    pub fn split_into(&self, trace: &BatchTrace, out: &mut Vec<DeviceTrace>) {
        let cap_hint = match self.strategy {
            ShardStrategy::ColumnWise => trace.lookups.len(),
            _ => trace.lookups.len() / self.devices + 1,
        };
        self.reset_split(trace, out, cap_hint);
        match self.strategy {
            ShardStrategy::ColumnWise => self.split_column(trace, out),
            _ => self.split_owner(trace, out),
        }
    }

    /// Size `out` to `devices` entries with cleared counters and cleared
    /// (capacity-retaining) lookup buffers.
    fn reset_split(&self, trace: &BatchTrace, out: &mut Vec<DeviceTrace>, cap_hint: usize) {
        out.truncate(self.devices);
        while out.len() < self.devices {
            out.push(DeviceTrace {
                trace: BatchTrace {
                    batch_index: trace.batch_index,
                    lookups: Vec::with_capacity(cap_hint),
                },
                bags: 0,
                exchange_bags: 0,
                intra_bags: 0,
                inter_bags: 0,
                node_led_inter_bags: 0,
                replica_ship_bags: 0,
                replicated: 0,
            });
        }
        for d in out.iter_mut() {
            d.trace.batch_index = trace.batch_index;
            d.trace.lookups.clear();
            d.bags = 0;
            d.exchange_bags = 0;
            d.intra_bags = 0;
            d.inter_bags = 0;
            d.node_led_inter_bags = 0;
            d.replica_ship_bags = 0;
            d.replicated = 0;
        }
    }

    /// Classify one freshly counted exchange bag into its interconnect
    /// tier: consumed locally (neither), on another device of the same
    /// node (intra), or in another node (inter). An inter bag also
    /// checks the node-distinct tally (`last_node_inter`, one slot per
    /// node — bag lookups are contiguous in the trace, so a per-node
    /// last-seen marker counts distinct `(node, bag)` pairs exactly):
    /// the node's first contributor "leads" the bag for hierarchical
    /// reduction.
    #[inline]
    fn tally_tier(
        &self,
        out: &mut DeviceTrace,
        last_node_inter: &mut [Option<(usize, u32)>],
        d: usize,
        home: usize,
        bag: (usize, u32),
    ) {
        if d == home {
            return;
        }
        if self.topology.same_node(d, home) {
            out.intra_bags += 1;
        } else {
            out.inter_bags += 1;
            let node = self.topology.node_of(d);
            if last_node_inter[node] != Some(bag) {
                last_node_inter[node] = Some(bag);
                out.node_led_inter_bags += 1;
            }
        }
    }

    fn split_owner(&self, trace: &BatchTrace, out: &mut [DeviceTrace]) {
        // lookups are sample-major then table then pooling slot, so one
        // bag's lookups are contiguous: a device contributes to a bag
        // iff its last-seen bag id changes
        let mut last_bag: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_remote: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_ship: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_node_inter: Vec<Option<(usize, u32)>> =
            vec![None; self.topology.nodes()];
        for (i, l) in trace.lookups.iter().enumerate() {
            let replicated = !self.replicas.is_empty()
                && self.replicas.is_replicated(l.table, l.row);
            let d = if replicated { self.replica_target(i) } else { self.device_of(l) };
            let bag = (i / self.lookups_per_sample, l.table);
            if last_bag[d] != Some(bag) {
                last_bag[d] = Some(bag);
                out[d].bags += 1;
            }
            if replicated {
                out[d].replicated += 1;
                // per-node replicas are served at the node leader; if
                // the home device is elsewhere in the node, the pooled
                // bag ships home over the intra-node links
                if d != self.home_of(i) && last_ship[d] != Some(bag) {
                    last_ship[d] = Some(bag);
                    out[d].replica_ship_bags += 1;
                }
            } else if last_remote[d] != Some(bag) {
                // only non-replicated contributions travel the all-to-all
                last_remote[d] = Some(bag);
                out[d].exchange_bags += 1;
                self.tally_tier(&mut out[d], &mut last_node_inter, d, self.home_of(i), bag);
            }
            out[d].trace.lookups.push(*l);
        }
    }

    fn split_column(&self, trace: &BatchTrace, out: &mut [DeviceTrace]) {
        let mut last_bag: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_remote: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_ship: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_node_inter: Vec<Option<(usize, u32)>> =
            vec![None; self.topology.nodes()];
        for (i, l) in trace.lookups.iter().enumerate() {
            let bag = (i / self.lookups_per_sample, l.table);
            if !self.replicas.is_empty() && self.replicas.is_replicated(l.table, l.row) {
                // the serving device holds the full replica: serve the
                // whole vector there, other devices skip this lookup
                // entirely
                let d = self.replica_target(i);
                if last_bag[d] != Some(bag) {
                    last_bag[d] = Some(bag);
                    out[d].bags += 1;
                }
                out[d].replicated += 1;
                if d != self.home_of(i) && last_ship[d] != Some(bag) {
                    last_ship[d] = Some(bag);
                    out[d].replica_ship_bags += 1;
                }
                out[d].trace.lookups.push(*l);
            } else {
                let home = self.home_of(i);
                for d in 0..self.devices {
                    if last_bag[d] != Some(bag) {
                        last_bag[d] = Some(bag);
                        out[d].bags += 1;
                    }
                    if last_remote[d] != Some(bag) {
                        last_remote[d] = Some(bag);
                        out[d].exchange_bags += 1;
                        self.tally_tier(&mut out[d], &mut last_node_inter, d, home, bag);
                    }
                    out[d].trace.lookups.push(*l);
                }
            }
        }
    }
}

/// Result of one batch's sharded embedding stage.
#[derive(Debug, Clone)]
pub struct ShardedStageResult {
    /// Embedding-stage wall cycles: the slowest device's gather+pool.
    pub cycles: u64,
    /// All-to-all exchange cycles charged after pooling (0 on 1 device).
    pub exchange_cycles: u64,
    /// Intra-node transfer cycles within `exchange_cycles` (the
    /// busiest device's intra-tier bytes over one per-device link).
    pub exchange_intra_cycles: u64,
    /// Inter-node transfer cycles within `exchange_cycles` (the
    /// busiest node's aggregate uplink bytes; 0 on a flat topology).
    pub exchange_inter_cycles: u64,
    /// Memory counters summed over devices.
    pub mem: MemCounts,
    /// Operation counters. Table/row sharding sums over devices; under
    /// column-wise the logical counts are reported (each lookup once,
    /// not once per dim-slice), so totals conserve against a 1-device
    /// run. `replicated_hits` is always the cross-device sum.
    pub ops: OpCounts,
    /// Per-device split of the same (physical per-device counts).
    pub per_device: Vec<DeviceCounters>,
}

/// Persistent multi-device embedding simulator: one [`EmbeddingSim`]
/// (local buffers, controller, DRAM state) per device plus the
/// partitioner and interconnect model.
pub struct ShardedEmbeddingSim {
    devices: Vec<EmbeddingSim>,
    partitioner: TablePartitioner,
    strategy: ShardStrategy,
    /// Interconnect shape + per-tier bandwidths (flat on one node).
    topology: Topology,
    hop_latency_cycles: u64,
    /// Bytes one device contributes per exchanged bag: the full pooled
    /// vector under table/row sharding, the device's dim-slice under
    /// column-wise (indexed by device).
    slice_bytes: Vec<u64>,
    /// Lines of one *full* embedding vector — what a replica hit costs
    /// on-chip, even on a device simulating only a dim-slice.
    full_vec_lines: u64,
    /// Bytes of one full embedding vector — what a per-node replica bag
    /// ships over the intra-node links from the leader to its home.
    full_vec_bytes: u64,
    /// Replicas held once per node (at the node leader) instead of on
    /// every device. Only meaningful on two-tier topologies.
    replicate_per_node: bool,
    /// Hierarchical reduction of row-hashed partial sums: a node's
    /// devices combine their partials for off-node bags over the intra
    /// links, shipping **one** combined partial per distinct bag up the
    /// uplink instead of one per contributor. Only meaningful for
    /// row-hashed sharding on a two-tier topology.
    reduce_inter: bool,
    pool: usize,
    /// Host worker threads for the per-device fan-out (`[sim] threads`).
    /// The devices are fully independent state machines, so any value
    /// yields bit-identical results; `1` runs them serially in-line.
    threads: usize,
    /// Reused per-batch split buffer (device `Vec<Lookup>`s keep their
    /// capacity across batches instead of reallocating).
    split_buf: Vec<DeviceTrace>,
    /// Speculative cross-batch window (`[sim] speculate_batches`): on a
    /// single device with a per-set-mergeable hierarchy,
    /// [`simulate_batches`](Self::simulate_batches) forks the warm state
    /// per batch and runs up to this many batches in parallel. `1`
    /// disables speculation entirely.
    speculate_batches: usize,
    /// Speculative forks merged without rerunning (zero-DRAM batches
    /// whose footprints were disjoint from every earlier window batch).
    committed_batches: u64,
    /// Speculative forks that failed the commit rule and were replayed
    /// serially on the true state.
    reran_batches: u64,
    /// Pooled footprint-union buffer for the disjointness check (reused
    /// across windows instead of reallocating).
    footprint_union: Vec<u64>,
}

/// Whether two sorted deduplicated id slices share no element.
fn sorted_disjoint(a: &[u64], b: &[u64]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return false,
        }
    }
    true
}

impl ShardedEmbeddingSim {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.sharding.devices.max(1);
        let emb = &cfg.workload.embedding;
        let strategy = cfg.sharding.strategy;
        let topo = Topology::from_config(&cfg.sharding);
        // the per-node knobs are only meaningful on a real hierarchy:
        // at nodes = 1 every [topology] key is inert, keeping flat runs
        // bit-identical to the pre-topology engine
        let per_node = cfg.sharding.topology.replicate_per_node && !topo.is_flat();
        // hierarchical reduction only makes sense where several devices
        // of one node hold *summable* partials of the same bag: row
        // hashing on a two-tier pod. Table-wise bags have a single
        // contributor; column slices concatenate and cannot be combined.
        let reduce_inter = cfg.sharding.topology.hierarchical_reduction
            && !topo.is_flat()
            && matches!(strategy, ShardStrategy::RowHashed)
            && n > 1;
        // node-aware placement (table-wise, two-tier only): start from
        // the uniform-weight balance; a profiled engine run refines it
        // with per-table traffic weights via `set_placement`
        let placement = if cfg.sharding.topology.node_aware_placement
            && !topo.is_flat()
            && n > 1
            && matches!(strategy, ShardStrategy::TableWise)
        {
            Some(TablePlacement::balance(&vec![1u64; emb.num_tables], &topo))
        } else {
            None
        };
        // replicas pin on-chip capacity (full vectors, even under
        // column-wise) — on every device, or only on each node's leader
        // in per-node mode. Single-device runs stay untouched so the
        // classic path is bit-identical regardless of knobs.
        let reserve = if n > 1 {
            cfg.sharding.replicate_top_k as u64 * emb.vec_bytes()
        } else {
            0
        };
        let mut slice_bytes = Vec::with_capacity(n);
        let devices = (0..n)
            .map(|d| {
                let mut dev_cfg = cfg.clone();
                if reserve > 0 && (!per_node || topo.is_leader(d)) {
                    let m = &mut dev_cfg.hardware.mem;
                    m.onchip_bytes =
                        m.onchip_bytes.saturating_sub(reserve).max(m.access_granularity);
                }
                // a device's sub-trace carries only its shard's lookups
                // per sample — align the per-core sample stride to that:
                // exactly `owned_tables * pool` table-wise (round-robin
                // gives device d one extra table when d < tables % n;
                // a node-aware placement supplies exact counts),
                // ~`tables * pool / n` row-hashed, and the full
                // `tables * pool` column-wise (every device sees every
                // lookup, just a narrower slice of it)
                let per_sample = match strategy {
                    ShardStrategy::TableWise => {
                        let owned = match &placement {
                            Some(p) => p.tables_on(d),
                            None => emb.num_tables / n + usize::from(d < emb.num_tables % n),
                        };
                        owned * emb.pool
                    }
                    ShardStrategy::RowHashed => emb.num_tables * emb.pool / n,
                    ShardStrategy::ColumnWise => {
                        let slice_dim =
                            (emb.dim / n + usize::from(d < emb.dim % n)).max(1);
                        dev_cfg.workload.embedding.dim = slice_dim;
                        emb.num_tables * emb.pool
                    }
                };
                slice_bytes.push(dev_cfg.workload.embedding.vec_bytes());
                let mut sim = EmbeddingSim::new(&dev_cfg);
                sim.set_lookups_per_sample(per_sample.max(1));
                sim
            })
            .collect();
        let mut partitioner = TablePartitioner::new(n, strategy, emb.num_tables * emb.pool);
        partitioner.set_topology(topo);
        partitioner.set_replicate_per_node(per_node);
        if let Some(p) = placement {
            partitioner.set_placement(p);
        }
        ShardedEmbeddingSim {
            devices,
            partitioner,
            strategy,
            topology: topo,
            hop_latency_cycles: cfg.sharding.hop_latency_cycles,
            slice_bytes,
            full_vec_lines: emb
                .vec_bytes()
                .div_ceil(cfg.hardware.mem.access_granularity)
                .max(1),
            full_vec_bytes: emb.vec_bytes(),
            replicate_per_node: per_node,
            reduce_inter,
            pool: emb.pool,
            threads: cfg.threads.max(1),
            split_buf: Vec::new(),
            speculate_batches: cfg.speculate_batches.max(1),
            committed_batches: 0,
            reran_batches: 0,
            footprint_union: Vec::new(),
        }
    }

    /// The resolved interconnect topology.
    pub fn topology(&self) -> Topology {
        self.topology
    }

    /// Whether this sim holds hot-row replicas once per node (at the
    /// node leaders) rather than on every device.
    pub fn replicates_per_node(&self) -> bool {
        self.replicate_per_node
    }

    /// Whether a profiled placement-weight refinement would be consumed
    /// — i.e. the constructor decided node-aware placement applies
    /// (table-wise strategy, two-tier topology, placement enabled).
    /// The engine consults this instead of re-deriving the rule.
    pub fn wants_placement_weights(&self) -> bool {
        self.partitioner.placement.is_some()
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Install the profiling-derived pin set on every device (the
    /// profile is workload-global; each shard pins its hot vectors).
    pub fn set_pin_set(&mut self, pins: PinSet) {
        for dev in &mut self.devices {
            dev.set_pin_set(pins.clone());
        }
    }

    /// Install distinct pin sets for node leaders and the other
    /// devices. Per-node replication pins the replica reserve only at
    /// each node's leader, so the remaining `devices_per_node - 1`
    /// devices have the full buffer available for pinning — the engine
    /// hands them the larger-budget set.
    pub fn set_pin_sets(&mut self, leaders: PinSet, members: PinSet) {
        for (d, dev) in self.devices.iter_mut().enumerate() {
            let pins = if self.topology.is_leader(d) {
                leaders.clone()
            } else {
                members.clone()
            };
            dev.set_pin_set(pins);
        }
    }

    /// Install the hot-row replica set on the partitioner (routing) and
    /// the serving devices (on-chip service) — every device, or only
    /// each node's leader in per-node replication mode. No-op on a
    /// single device, which stays bit-identical to the classic path.
    pub fn set_replicas(&mut self, replicas: HotRowReplicator) {
        if self.devices.len() == 1 {
            return;
        }
        self.partitioner.set_replicas(replicas.clone());
        for (d, dev) in self.devices.iter_mut().enumerate() {
            if self.replicate_per_node && !self.topology.is_leader(d) {
                // non-leaders hold no replica copy and are never routed
                // a replicated lookup
                continue;
            }
            // replicas are stored whole, so a hit costs the full
            // vector's lines even on a dim-slice device
            dev.set_replicas(replicas.clone(), self.full_vec_lines);
        }
    }

    /// Refine the node-aware table placement with profiled per-table
    /// weights (typically each table's non-replicated lookup count).
    /// Only meaningful for table-wise sharding on a two-tier topology
    /// with `topology.node_aware_placement` enabled — a no-op otherwise,
    /// so callers can invoke it unconditionally. Call before the first
    /// batch: the per-device sample strides are re-derived from the new
    /// table→device map.
    pub fn set_placement_weights(&mut self, weights: &[u64]) {
        if self.devices.len() == 1
            || self.topology.is_flat()
            || !matches!(self.strategy, ShardStrategy::TableWise)
            || self.partitioner.placement.is_none()
        {
            return;
        }
        let placement = TablePlacement::balance(weights, &self.topology);
        for (d, dev) in self.devices.iter_mut().enumerate() {
            dev.set_lookups_per_sample((placement.tables_on(d) * self.pool).max(1));
        }
        self.partitioner.set_placement(placement);
    }

    /// Exchange-phase cycles from per-device intra-tier bytes and
    /// per-device inter-tier bytes: the intra tier drains the busiest
    /// *device's* bytes over its own link; the inter tier drains the
    /// busiest *node's* aggregate bytes over its shared uplink. The two
    /// drains are serialized after one hop launch. On a flat topology
    /// every byte is intra and the result is bit-identical to the
    /// classic `hop + ceil(max_send / link)` accounting.
    fn exchange_cycles(
        &self,
        intra_bytes: &[u64],
        inter_bytes: &[u64],
    ) -> topology::ExchangeCycles {
        let intra_max = intra_bytes.iter().copied().max().unwrap_or(0);
        let mut node_bytes = vec![0u64; self.topology.nodes()];
        for (d, &b) in inter_bytes.iter().enumerate() {
            node_bytes[self.topology.node_of(d)] += b;
        }
        let inter_max = node_bytes.iter().copied().max().unwrap_or(0);
        self.topology
            .exchange_cycles(self.hop_latency_cycles, intra_max, inter_max)
    }

    /// Wrap a single-device stage result (exchange-free, device 0).
    fn single_device_result(
        r: crate::engine::embedding::EmbeddingStageResult,
    ) -> ShardedStageResult {
        ShardedStageResult {
            cycles: r.cycles,
            exchange_cycles: 0,
            exchange_intra_cycles: 0,
            exchange_inter_cycles: 0,
            mem: r.mem,
            ops: r.ops,
            per_device: vec![DeviceCounters {
                device: 0,
                cycles: r.cycles,
                exchange_bytes: 0,
                inter_bytes: 0,
                mem: r.mem,
                ops: r.ops,
            }],
        }
    }

    /// Simulate one batch across all devices.
    pub fn simulate_batch(&mut self, trace: &BatchTrace) -> ShardedStageResult {
        let n = self.devices.len();
        if n == 1 {
            // single-device fast path: bit-identical to the classic
            // EmbeddingSim on the unsplit trace, exchange-free
            let r = self.devices[0].simulate_batch(trace);
            return Self::single_device_result(r);
        }

        // reuse the split buffer across batches (taken to keep the
        // borrow checker happy alongside `self.devices` below)
        let mut split = std::mem::take(&mut self.split_buf);
        self.partitioner.split_into(trace, &mut split);

        // Per-device fan-out: each device is a fully self-contained
        // state machine (its own buffers, controller, DRAM rows, cycle
        // cursor), so the N simulations are embarrassingly parallel.
        // Workers own contiguous device chunks and results come back in
        // device order, so the accumulation below is bit-identical to
        // the serial loop for any thread count.
        let workers = self.threads.min(n);
        let results: Vec<crate::engine::embedding::EmbeddingStageResult> = if workers > 1 {
            let chunk = n.div_ceil(workers);
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .devices
                    .chunks_mut(chunk)
                    .zip(split.chunks(chunk))
                    .map(|(sims, parts)| {
                        s.spawn(move || {
                            sims.iter_mut()
                                .zip(parts)
                                .map(|(sim, part)| {
                                    sim.simulate_batch_with_bags(&part.trace, part.bags)
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("device worker panicked"))
                    .collect()
            })
        } else {
            self.devices
                .iter_mut()
                .zip(&split)
                .map(|(sim, part)| sim.simulate_batch_with_bags(&part.trace, part.bags))
                .collect()
        };

        let mut mem = MemCounts::default();
        let mut ops = OpCounts::default();
        let mut per_device = Vec::with_capacity(n);
        let mut intra_bytes = Vec::with_capacity(n);
        let mut inter_bytes = Vec::with_capacity(n);
        let mut wall = 0u64;
        for (device, (r, part)) in results.iter().zip(&split).enumerate() {
            // the partitioner knows the exact distinct-bag count of each
            // sub-trace (rerouted hot rows break pool alignment)
            wall = wall.max(r.cycles);
            mem.add(&r.mem);
            ops.add(&r.ops);
            // pooled output for the exchange-charged bags; (n-1)/n of it
            // is remote (the classic flat accounting, kept bit-identical).
            // The travelling share splits across the tiers in exact
            // proportion to where each bag's home device sits: same node
            // (intra links) or another node (the node uplink).
            // eonsim-lint: allow(underflow, reason = "n = devices.len() >= 1 is enforced by config validate (sharding.devices >= 1), so n - 1 cannot wrap")
            let total = part.exchange_bags * self.slice_bytes[device] * (n as u64 - 1)
                / n as u64;
            let travel = part.intra_bags + part.inter_bags;
            let mut inter = if travel > 0 { total * part.inter_bags / travel } else { 0 };
            if self.reduce_inter && part.inter_bags > 0 {
                // hierarchical reduction: only the bags this device
                // *leads* for its node cross the uplink (as the node's
                // combined partial); its other off-node partials ship
                // intra-node to the bag's combiner instead. The moved
                // bytes land in the intra tier below (`total - inter`),
                // so the device's total exchange volume is conserved —
                // only the tier split (and therefore the uplink price)
                // changes.
                inter = inter * part.node_led_inter_bags / part.inter_bags;
            }
            // per-node replica bags ship whole from the node leader to
            // their home device over the intra links (same-node by
            // construction). Per-device replicas live at home: free.
            // eonsim-lint: allow(underflow, reason = "inter <= total by construction: it is total scaled by the ratios inter_bags/travel and node_led_inter_bags/inter_bags, both <= 1")
            let intra = (total - inter) + part.replica_ship_bags * self.full_vec_bytes;
            intra_bytes.push(intra);
            inter_bytes.push(inter);
            per_device.push(DeviceCounters {
                device,
                cycles: r.cycles,
                exchange_bytes: intra + inter,
                inter_bytes: inter,
                mem: r.mem,
                ops: r.ops,
            });
        }
        if matches!(self.strategy, ShardStrategy::ColumnWise) {
            // every device walked (its slice of) every lookup: report
            // logical op counts so totals conserve against one device,
            // keeping only the cross-device replica-hit sum
            let lookups = trace.lookups.len() as u64;
            let bags = lookups / self.pool.max(1) as u64;
            ops = OpCounts {
                macs: 0,
                // summing a bag of k vectors takes k - 1 adds
                vpu_ops: lookups.saturating_sub(bags),
                lookups,
                replicated_hits: per_device
                    .iter()
                    .map(|d| d.ops.replicated_hits)
                    .sum(),
            };
        }
        self.split_buf = split;
        let ex = self.exchange_cycles(&intra_bytes, &inter_bytes);
        ShardedStageResult {
            cycles: wall,
            exchange_cycles: ex.total,
            exchange_intra_cycles: ex.intra,
            exchange_inter_cycles: ex.inter,
            mem,
            ops,
            per_device,
        }
    }

    /// Simulate a sequence of batches, exploiting the speculative
    /// cross-batch window (`[sim] speculate_batches`) when it applies: a
    /// single device whose hierarchy is per-set mergeable
    /// ([`EmbeddingSim::speculation_safe`]). Each window forks the warm
    /// device state once per batch and runs the forks in parallel (via
    /// [`crate::parallel`]), then commits sequentially: the first batch
    /// by wholesale state replacement (its fork ran from the true
    /// state), later ones only when they issued zero off-chip lines
    /// *and* their conservative set footprint is disjoint from every
    /// earlier batch in the window — anything else replays serially on
    /// the true state. Reports are byte-identical to the serial
    /// [`simulate_batch`](Self::simulate_batch) loop at every setting.
    pub fn simulate_batches(&mut self, traces: &[&BatchTrace]) -> Vec<ShardedStageResult> {
        let k = self.speculate_batches;
        if self.devices.len() != 1
            || k <= 1
            || traces.len() <= 1
            || !self.devices[0].speculation_safe()
        {
            return traces.iter().map(|t| self.simulate_batch(t)).collect();
        }
        let mut out = Vec::with_capacity(traces.len());
        let mut union = std::mem::take(&mut self.footprint_union);
        for window in traces.chunks(k) {
            if window.len() == 1 {
                out.push(self.simulate_batch(window[0]));
                continue;
            }
            union.clear();
            let base = self.devices[0].snapshot_stats();
            let dev0 = &self.devices[0];
            let forks = crate::parallel::parallel_map_with(
                self.threads,
                window,
                |t: &&BatchTrace| {
                    let mut fork = dev0.clone();
                    let mut fp = Vec::new();
                    fork.batch_footprint(t, &mut fp);
                    let r = fork.simulate_batch(t);
                    Ok((fork, r, fp))
                },
            )
            .expect("speculative fork worker failed");
            for (i, ((fork, r, fp), trace)) in
                forks.into_iter().zip(window).enumerate()
            {
                if i == 0 {
                    // fork of the true state: wholesale replacement is
                    // exact for any policy and any DRAM traffic
                    self.devices[0] = fork;
                    out.push(Self::single_device_result(r));
                } else if fork.offchip_issued() == base.issued()
                    && sorted_disjoint(&fp, &union)
                {
                    self.devices[0].absorb_fork(&fork, &base, &fp);
                    self.committed_batches += 1;
                    out.push(Self::single_device_result(r));
                } else {
                    // commit rule failed: replay on the true warm state
                    self.reran_batches += 1;
                    out.push(self.simulate_batch(trace));
                }
                union.extend_from_slice(&fp);
                union.sort_unstable();
                union.dedup();
            }
        }
        self.footprint_union = union;
        out
    }

    /// Speculative forks merged without rerunning (over this sim's
    /// lifetime). Observability for tests and the bench harness.
    pub fn speculative_commits(&self) -> u64 {
        self.committed_batches
    }

    /// Speculative forks that failed the commit rule and were replayed
    /// serially.
    pub fn speculative_reruns(&self) -> u64 {
        self.reran_batches
    }

    /// Toggle the vectorized embedding hot path on every device
    /// (`[sim] vectorized`; differential-testing hook).
    pub fn set_vectorized(&mut self, on: bool) {
        for dev in &mut self.devices {
            dev.set_vectorized(on);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, OnchipPolicy};
    use crate::mem::policy::pinning::Profile;
    use crate::trace::TraceGenerator;

    fn small_cfg(devices: usize, strategy: ShardStrategy) -> SimConfig {
        let mut cfg = presets::tpuv6e_dlrm_small();
        cfg.workload.batch_size = 32;
        cfg.workload.embedding.num_tables = 8;
        cfg.workload.embedding.rows_per_table = 20_000;
        cfg.workload.embedding.pool = 16;
        cfg.workload.trace.alpha = 1.1;
        cfg.hardware.mem.policy = OnchipPolicy::Spm;
        cfg.sharding.devices = devices;
        cfg.sharding.strategy = strategy;
        cfg
    }

    fn one_batch(cfg: &SimConfig) -> BatchTrace {
        TraceGenerator::new(&cfg.workload).unwrap().next_batch()
    }

    #[test]
    fn table_wise_assigns_whole_tables() {
        let p = TablePartitioner::new(4, ShardStrategy::TableWise, 128);
        for table in 0..16u32 {
            let d = p.device_of(&Lookup { table, row: 0 });
            assert_eq!(d, table as usize % 4);
            // rows never move a table-wise lookup
            assert_eq!(d, p.device_of(&Lookup { table, row: 12345 }));
        }
    }

    #[test]
    fn row_hashed_spreads_rows_of_one_table() {
        let p = TablePartitioner::new(4, ShardStrategy::RowHashed, 128);
        let mut seen = [false; 4];
        for row in 0..64 {
            seen[p.device_of(&Lookup { table: 0, row })] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 rows must touch all 4 devices");
    }

    #[test]
    fn split_conserves_and_preserves_order() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::RowHashed,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        // single linear merge walk: without replication each lookup's
        // device is a pure function of its value, so walking the original
        // trace once and advancing that device's cursor verifies both
        // placement and order (the old per-device `cursor.any` subsequence
        // scan was O(n²) and dominated the release suite's wall time)
        let mut cursors = vec![0usize; split.len()];
        for l in &trace.lookups {
            let d = p.device_of(l);
            assert_eq!(
                split[d].trace.lookups.get(cursors[d]),
                Some(l),
                "order violated for {l:?} on device {d}"
            );
            cursors[d] += 1;
        }
        for (d, dt) in split.iter().enumerate() {
            assert_eq!(cursors[d], dt.trace.lookups.len(), "device {d} fully consumed");
        }
    }

    #[test]
    fn table_wise_bag_count_is_owned_tables_times_batch() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::TableWise,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        // 8 tables over 4 devices = 2 tables each; every (sample, table)
        // bag is complete on its owner — and without replication every
        // bag travels the exchange
        for d in &split {
            assert_eq!(d.bags, 2 * cfg.workload.batch_size as u64);
            assert_eq!(d.exchange_bags, d.bags);
            assert_eq!(d.replicated, 0);
        }
    }

    #[test]
    fn column_split_places_every_lookup_on_every_device() {
        let cfg = small_cfg(4, ShardStrategy::ColumnWise);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::ColumnWise,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        let bags = trace.lookups.len() as u64 / cfg.workload.embedding.pool as u64;
        for d in &split {
            assert_eq!(d.trace.lookups, trace.lookups, "full trace on each device");
            assert_eq!(d.bags, bags, "a slice of every bag on each device");
            assert_eq!(d.exchange_bags, bags);
        }
    }

    #[test]
    fn replicated_lookups_route_to_sample_home_device() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        // replicate this trace's own hottest rows
        let mut profile = Profile::new();
        for l in &trace.lookups {
            profile.record(l.table, l.row);
        }
        let replicas = replicate::HotRowReplicator::from_profile(&profile, 64);
        let mut p = TablePartitioner::new(4, ShardStrategy::TableWise, lps);
        p.set_replicas(replicas.clone());
        let split = p.split(&trace);
        // conservation: every lookup still lands exactly once
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        let replicated: u64 = split.iter().map(|d| d.replicated).sum();
        assert!(replicated > 0, "hot rows must reroute under a skewed trace");
        // a replicated lookup sits on its sample's home device, not its
        // table's owner; non-replicated lookups stay with their owner
        let mut expected: Vec<Vec<Lookup>> = vec![Vec::new(); 4];
        for (i, l) in trace.lookups.iter().enumerate() {
            let d = if replicas.is_replicated(l.table, l.row) {
                (i / lps) % 4 // sample's home device
            } else {
                l.table as usize % 4 // table-wise owner
            };
            expected[d].push(*l);
        }
        for (d, dt) in split.iter().enumerate() {
            assert_eq!(dt.trace.lookups, expected[d], "device {d} placement");
        }
        // exchange never grows under replication
        let plain = TablePartitioner::new(4, ShardStrategy::TableWise, lps).split(&trace);
        for (with, without) in split.iter().zip(&plain) {
            assert!(with.exchange_bags <= without.exchange_bags);
        }
    }

    #[test]
    fn split_tier_counts_partition_the_exchange_bags() {
        // 2×4 two-tier: every exchange bag is local, intra, or inter —
        // and the tier counts are exact (homes round-robin the samples)
        let cfg = small_cfg(8, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        let mut p = TablePartitioner::new(8, ShardStrategy::TableWise, lps);
        p.set_topology(Topology::two_tier(2, 4, 100.0, 12.5));
        let split = p.split(&trace);
        for (d, dt) in split.iter().enumerate() {
            assert!(dt.intra_bags + dt.inter_bags <= dt.exchange_bags, "device {d}");
            assert!(dt.inter_bags > 0, "device {d} must send across nodes");
            assert_eq!(dt.replica_ship_bags, 0);
        }
        // flat topology never records an inter-tier bag
        let flat = TablePartitioner::new(8, ShardStrategy::TableWise, lps).split(&trace);
        for (two, one) in split.iter().zip(&flat) {
            assert_eq!(one.inter_bags, 0);
            assert_eq!(one.intra_bags + one.inter_bags, two.intra_bags + two.inter_bags);
            assert_eq!(two.exchange_bags, one.exchange_bags);
        }
    }

    #[test]
    fn per_node_replication_routes_to_node_leaders() {
        let cfg = small_cfg(8, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        let mut profile = Profile::new();
        for l in &trace.lookups {
            profile.record(l.table, l.row);
        }
        let replicas = replicate::HotRowReplicator::from_profile(&profile, 64);
        let topo = Topology::two_tier(2, 4, 100.0, 12.5);
        let mut p = TablePartitioner::new(8, ShardStrategy::TableWise, lps);
        p.set_topology(topo);
        p.set_replicas(replicas.clone());
        p.set_replicate_per_node(true);
        let split = p.split(&trace);
        // conservation, and replicated lookups land only on leaders
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        let replicated: u64 = split.iter().map(|d| d.replicated).sum();
        assert!(replicated > 0, "hot rows must reroute under a skewed trace");
        for (d, dt) in split.iter().enumerate() {
            if !topo.is_leader(d) {
                assert_eq!(dt.replicated, 0, "non-leader {d} must hold no replicas");
                assert_eq!(dt.replica_ship_bags, 0);
            }
        }
        // leaders ship replica bags to homes elsewhere in their node
        assert!(
            split.iter().map(|d| d.replica_ship_bags).sum::<u64>() > 0,
            "3 of 4 homes per node sit off-leader"
        );
    }

    #[test]
    fn node_led_inter_bags_count_distinct_off_node_bags() {
        let lps_of = |cfg: &SimConfig| {
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool
        };
        // row-hashed 2×4: several devices of a node hold partials of the
        // same off-node bag, so the node-distinct count is strictly
        // smaller than the contribution count
        let cfg = small_cfg(8, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let mut p = TablePartitioner::new(8, ShardStrategy::RowHashed, lps_of(&cfg));
        let topo = Topology::two_tier(2, 4, 100.0, 12.5);
        p.set_topology(topo);
        let split = p.split(&trace);
        for node in 0..2 {
            let devs = (node * 4)..(node * 4 + 4);
            let led: u64 = devs.clone().map(|d| split[d].node_led_inter_bags).sum();
            let contrib: u64 = devs.map(|d| split[d].inter_bags).sum();
            assert!(led > 0 && led < contrib, "node {node}: led {led} vs {contrib}");
        }
        for d in &split {
            assert!(d.node_led_inter_bags <= d.inter_bags);
        }
        // table-wise: one contributor per bag, so leading == contributing
        let cfg = small_cfg(8, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let mut p = TablePartitioner::new(8, ShardStrategy::TableWise, lps_of(&cfg));
        p.set_topology(topo);
        for d in p.split(&trace) {
            assert_eq!(d.node_led_inter_bags, d.inter_bags);
        }
        // flat topologies record no inter (and so no led) bags at all
        let p = TablePartitioner::new(8, ShardStrategy::RowHashed, lps_of(&cfg));
        for d in p.split(&trace) {
            assert_eq!(d.inter_bags, 0);
            assert_eq!(d.node_led_inter_bags, 0);
        }
    }

    #[test]
    fn hierarchical_reduction_moves_uplink_bytes_to_the_intra_tier() {
        let mut cfg = small_cfg(8, ShardStrategy::RowHashed);
        cfg.sharding.topology.nodes = 2;
        let trace = one_batch(&cfg);
        let plain = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        let mut rcfg = cfg.clone();
        rcfg.sharding.topology.hierarchical_reduction = true;
        let reduced = ShardedEmbeddingSim::new(&rcfg).simulate_batch(&trace);
        // per-device total exchange volume is conserved; only the tier
        // split moves
        for (a, b) in plain.per_device.iter().zip(&reduced.per_device) {
            assert_eq!(a.exchange_bytes, b.exchange_bytes, "device {}", a.device);
            assert!(b.inter_bytes < a.inter_bytes, "device {}", a.device);
        }
        // combining partials shrinks the serialized uplink drain, and
        // with it the whole exchange phase
        assert!(reduced.exchange_inter_cycles < plain.exchange_inter_cycles);
        assert!(reduced.exchange_cycles < plain.exchange_cycles);
        // compute counters are untouched — reduction re-prices transfers
        assert_eq!(plain.mem, reduced.mem);
        assert_eq!(plain.ops, reduced.ops);
    }

    #[test]
    fn per_device_replication_never_ships_replica_bags() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        let mut profile = Profile::new();
        for l in &trace.lookups {
            profile.record(l.table, l.row);
        }
        let mut p = TablePartitioner::new(4, ShardStrategy::TableWise, lps);
        p.set_replicas(replicate::HotRowReplicator::from_profile(&profile, 64));
        for d in p.split(&trace) {
            assert_eq!(d.replica_ship_bags, 0, "home-device replicas never travel");
        }
    }

    #[test]
    fn placement_overrides_table_owner() {
        let lps = 128;
        let mut p = TablePartitioner::new(4, ShardStrategy::TableWise, lps);
        let topo = Topology::two_tier(2, 2, 100.0, 12.5);
        p.set_topology(topo);
        p.set_placement(TablePlacement::balance(&[9, 9, 1, 1], &topo));
        let owners: Vec<usize> = (0..4u32)
            .map(|table| p.device_of(&Lookup { table, row: 0 }))
            .collect();
        // the two heavy tables split across nodes
        assert_ne!(topo.node_of(owners[0]), topo.node_of(owners[1]));
        // every table still owned by exactly one device
        let trace = one_batch(&small_cfg(4, ShardStrategy::TableWise));
        let split = p.split(&trace);
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
    }

    #[test]
    fn two_tier_exchange_bytes_conserve_per_device() {
        // intra + inter == the flat run's per-device exchange bytes, and
        // the tier cycle split sums (with the hop) to the total
        let mut cfg = small_cfg(8, ShardStrategy::TableWise);
        cfg.sharding.topology.nodes = 2;
        cfg.sharding.topology.inter_link_bytes_per_cycle = 12.5;
        let trace = one_batch(&cfg);
        let two = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        let flat = ShardedEmbeddingSim::new(&small_cfg(8, ShardStrategy::TableWise))
            .simulate_batch(&trace);
        for (t, f) in two.per_device.iter().zip(&flat.per_device) {
            assert_eq!(t.exchange_bytes, f.exchange_bytes, "device {}", t.device);
            assert!(t.inter_bytes > 0 && t.inter_bytes < t.exchange_bytes);
            assert_eq!(f.inter_bytes, 0);
        }
        assert!(two.exchange_intra_cycles > 0 && two.exchange_inter_cycles > 0);
        assert_eq!(
            two.exchange_cycles,
            cfg.sharding.hop_latency_cycles
                + two.exchange_intra_cycles
                + two.exchange_inter_cycles
        );
        assert_eq!(flat.exchange_inter_cycles, 0);
        assert_eq!(flat.exchange_cycles, two.exchange_cycles - two.exchange_inter_cycles
            - two.exchange_intra_cycles + flat.exchange_intra_cycles);
    }

    #[test]
    fn single_device_is_bit_identical_to_embedding_sim() {
        let cfg = small_cfg(1, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let mut plain = EmbeddingSim::new(&cfg);
        let mut sharded = ShardedEmbeddingSim::new(&cfg);
        let a = plain.simulate_batch(&trace);
        let b = sharded.simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.mem, b.mem);
        assert_eq!(b.exchange_cycles, 0);
        assert_eq!(b.per_device.len(), 1);
    }

    #[test]
    fn counters_conserve_across_devices_under_spm() {
        // SPM streams every line off-chip, so per-device sums must equal
        // the 1-device run exactly, for both owner strategies
        for strategy in [ShardStrategy::TableWise, ShardStrategy::RowHashed] {
            let cfg1 = small_cfg(1, strategy);
            let trace = one_batch(&cfg1);
            let one = ShardedEmbeddingSim::new(&cfg1).simulate_batch(&trace);
            let cfg4 = small_cfg(4, strategy);
            let mut sim4 = ShardedEmbeddingSim::new(&cfg4);
            let four = sim4.simulate_batch(&trace);
            assert_eq!(four.mem.offchip_reads, one.mem.offchip_reads, "{strategy:?}");
            assert_eq!(four.mem.hits, one.mem.hits, "{strategy:?}");
            assert_eq!(four.ops.lookups, one.ops.lookups, "{strategy:?}");
            let dev_sum: u64 = four.per_device.iter().map(|d| d.mem.offchip_reads).sum();
            assert_eq!(dev_sum, one.mem.offchip_reads, "{strategy:?}");
        }
    }

    #[test]
    fn column_wise_conserves_logical_counters() {
        // dim 128 over 4 devices = 32-dim slices of 2 lines each: line
        // traffic and logical op counts match the 1-device run exactly
        let cfg1 = small_cfg(1, ShardStrategy::TableWise);
        let trace = one_batch(&cfg1);
        let one = ShardedEmbeddingSim::new(&cfg1).simulate_batch(&trace);
        let cfg4 = small_cfg(4, ShardStrategy::ColumnWise);
        let four = ShardedEmbeddingSim::new(&cfg4).simulate_batch(&trace);
        assert_eq!(four.mem.offchip_reads, one.mem.offchip_reads);
        assert_eq!(four.ops.lookups, one.ops.lookups);
        assert_eq!(four.ops.vpu_ops, one.ops.vpu_ops);
        // per-device: every device walked every lookup at a quarter dim
        for d in &four.per_device {
            assert_eq!(d.ops.lookups, one.ops.lookups);
            assert_eq!(d.mem.offchip_reads, one.mem.offchip_reads / 4);
        }
    }

    #[test]
    fn split_into_reuses_buffers_and_matches_split() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        let mut gen = TraceGenerator::new(&cfg.workload).unwrap();
        let (t1, t2) = (gen.next_batch(), gen.next_batch());
        let p = TablePartitioner::new(4, ShardStrategy::RowHashed, lps);
        let mut buf = Vec::new();
        for t in [&t1, &t2] {
            // the reused buffer must match a fresh split exactly, with
            // stale counters/lookups from the previous batch cleared
            p.split_into(t, &mut buf);
            let fresh = p.split(t);
            assert_eq!(buf.len(), fresh.len());
            for (a, b) in buf.iter().zip(&fresh) {
                assert_eq!(a.trace.batch_index, t.batch_index);
                assert_eq!(a.trace.lookups, b.trace.lookups);
                assert_eq!(a.bags, b.bags);
                assert_eq!(a.exchange_bags, b.exchange_bags);
                assert_eq!(a.replicated, b.replicated);
            }
        }
    }

    #[test]
    fn threaded_fanout_is_bit_identical_to_serial() {
        // worker count is a pure host knob: every counter, per-device
        // split, and cycle total must be unchanged — including uneven
        // device/worker chunkings (4 devices over 3 workers)
        for strategy in [
            ShardStrategy::TableWise,
            ShardStrategy::RowHashed,
            ShardStrategy::ColumnWise,
        ] {
            let trace = one_batch(&small_cfg(4, strategy));
            let run = |threads: usize| {
                let mut cfg = small_cfg(4, strategy);
                cfg.threads = threads;
                let mut sim = ShardedEmbeddingSim::new(&cfg);
                // two batches so persistent per-device state is exercised
                let a = sim.simulate_batch(&trace);
                let b = sim.simulate_batch(&trace);
                (a, b)
            };
            let serial = run(1);
            for threads in [2usize, 3, 4, 16] {
                let parallel = run(threads);
                for ((s, p), which) in [(&serial.0, &parallel.0), (&serial.1, &parallel.1)]
                    .into_iter()
                    .zip(["first", "second"])
                {
                    assert_eq!(s.cycles, p.cycles, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.exchange_cycles, p.exchange_cycles, "{strategy:?} x{threads}");
                    assert_eq!(s.mem, p.mem, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.ops, p.ops, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.per_device, p.per_device, "{strategy:?} x{threads} {which}");
                }
            }
        }
    }

    #[test]
    fn sharding_is_deterministic() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let a = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        let b = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.exchange_cycles, b.exchange_cycles);
        assert_eq!(a.mem, b.mem);
    }

    #[test]
    fn more_devices_never_slow_the_embedding_stage() {
        let mut prev = u64::MAX;
        for devices in [1usize, 2, 4] {
            let cfg = small_cfg(devices, ShardStrategy::TableWise);
            let trace = one_batch(&cfg);
            let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
            assert!(
                r.cycles <= prev,
                "{devices} devices: {} cycles > previous {prev}",
                r.cycles
            );
            prev = r.cycles;
        }
    }

    #[test]
    fn exchange_positive_on_multi_device_and_scales_with_links() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert!(r.exchange_cycles > cfg.sharding.hop_latency_cycles);

        let mut fast = cfg.clone();
        fast.sharding.link_bytes_per_cycle *= 8.0;
        let rf = ShardedEmbeddingSim::new(&fast).simulate_batch(&trace);
        assert!(rf.exchange_cycles < r.exchange_cycles, "faster links must shrink exchange");
    }

    #[test]
    fn row_hashed_exchanges_more_than_table_wise() {
        // row-hashing leaves nearly every device with partials for
        // nearly every bag — the classic row-wise reduce cost
        let cfg_t = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg_t);
        let t = ShardedEmbeddingSim::new(&cfg_t).simulate_batch(&trace);
        let cfg_r = small_cfg(4, ShardStrategy::RowHashed);
        let r = ShardedEmbeddingSim::new(&cfg_r).simulate_batch(&trace);
        let sum = |x: &ShardedStageResult| -> u64 {
            x.per_device.iter().map(|d| d.exchange_bytes).sum()
        };
        assert!(sum(&r) > sum(&t), "row {} !> table {}", sum(&r), sum(&t));
    }

    #[test]
    fn replication_serves_hot_rows_on_chip_and_shrinks_exchange() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let plain = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);

        let mut rcfg = cfg.clone();
        rcfg.sharding.replicate_top_k = 256;
        let mut sim = ShardedEmbeddingSim::new(&rcfg);
        sim.set_replicas(
            replicate::HotRowReplicator::from_workload(&rcfg.workload, 256).unwrap(),
        );
        let rep = sim.simulate_batch(&trace);
        assert!(rep.ops.replicated_hits > 0);
        assert_eq!(rep.ops.lookups, plain.ops.lookups, "lookups conserve");
        // replica hits convert off-chip lines to on-chip hits, 8 lines
        // per 128-dim vector
        assert_eq!(
            rep.mem.offchip_reads + rep.ops.replicated_hits * 8,
            plain.mem.offchip_reads
        );
        assert!(rep.exchange_cycles <= plain.exchange_cycles);
    }

    fn assert_sharded_eq(a: &ShardedStageResult, b: &ShardedStageResult, ctx: &str) {
        assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
        assert_eq!(a.exchange_cycles, b.exchange_cycles, "{ctx}: exchange");
        assert_eq!(a.mem, b.mem, "{ctx}: mem counters");
        assert_eq!(a.ops, b.ops, "{ctx}: op counters");
        assert_eq!(a.per_device, b.per_device, "{ctx}: per-device");
    }

    fn spec_cfg(policy: OnchipPolicy, speculate: usize) -> SimConfig {
        let mut cfg = small_cfg(1, ShardStrategy::TableWise);
        cfg.hardware.mem.policy = policy;
        cfg.speculate_batches = speculate;
        cfg.threads = 2;
        cfg
    }

    #[test]
    fn speculative_window_bit_identical_to_serial() {
        // The headline soundness property of `[sim] speculate_batches`:
        // for K in {1, 2, 4} the windowed path must reproduce the serial
        // per-batch loop byte-for-byte — including the DRAM row-buffer,
        // controller and cycle-cursor state it leaves behind, which the
        // trailing extra batch (simulated serially on both sims) checks.
        for policy in [
            OnchipPolicy::Spm,
            OnchipPolicy::Cache(crate::config::CachePolicyKind::Lru),
            OnchipPolicy::Cache(crate::config::CachePolicyKind::Srrip),
        ] {
            for k in [1usize, 2, 4] {
                let cfg = spec_cfg(policy, k);
                let mut generator = TraceGenerator::new(&cfg.workload).unwrap();
                let traces: Vec<BatchTrace> =
                    (0..5).map(|_| generator.next_batch()).collect();
                let refs: Vec<&BatchTrace> = traces.iter().collect();

                let mut spec = ShardedEmbeddingSim::new(&cfg);
                let windowed = spec.simulate_batches(&refs);

                let mut serial_cfg = cfg.clone();
                serial_cfg.speculate_batches = 1;
                let mut serial = ShardedEmbeddingSim::new(&serial_cfg);
                for (b, trace) in traces.iter().enumerate() {
                    let want = serial.simulate_batch(trace);
                    assert_sharded_eq(
                        &windowed[b],
                        &want,
                        &format!("policy {policy:?} K={k} batch {b}"),
                    );
                }
                // follow-up batch exercises the post-window warm state
                let next = generator.next_batch();
                let a = spec.simulate_batch(&next);
                let b = serial.simulate_batch(&next);
                assert_sharded_eq(&a, &b, &format!("policy {policy:?} K={k} follow-up"));
            }
        }
    }

    #[test]
    fn speculation_commits_zero_dram_batches() {
        // A buffer big enough to absorb the whole working set: after the
        // first (wholesale-committed) batch warms it, later batches in a
        // window issue zero off-chip lines over the already-resident
        // sets... but their footprints overlap the first batch's, so the
        // commit that proves the machinery works is the *replica* one —
        // fully replicated traffic has an empty footprint and no DRAM.
        let mut cfg = spec_cfg(OnchipPolicy::Spm, 4);
        cfg.sharding.replicate_top_k = 512;
        cfg.workload.embedding.rows_per_table = 400; // everything replicable
        let mut generator = TraceGenerator::new(&cfg.workload).unwrap();
        let traces: Vec<BatchTrace> =
            (0..4).map(|_| generator.next_batch()).collect();
        let refs: Vec<&BatchTrace> = traces.iter().collect();

        let mut sim = ShardedEmbeddingSim::new(&cfg);
        // replicate every row of the tiny tables -> every lookup is a
        // replica hit -> zero DRAM and an empty footprint per batch.
        // (Install directly: `set_replicas` is a routing no-op on one
        // device, so drive the device itself like the engine would.)
        let mut profile = Profile::new();
        for t in &traces {
            for l in &t.lookups {
                profile.record(l.table, l.row);
            }
        }
        let replicas =
            replicate::HotRowReplicator::from_profile(&profile, profile.unique_vectors());
        sim.devices[0].set_replicas(replicas, 8);
        let results = sim.simulate_batches(&refs);
        assert_eq!(results.len(), 4);
        assert!(
            sim.speculative_commits() > 0,
            "fully replicated windows must commit speculatively \
             (commits {}, reruns {})",
            sim.speculative_commits(),
            sim.speculative_reruns()
        );
        assert_eq!(sim.speculative_reruns(), 0, "nothing to rerun");
        for r in &results {
            assert_eq!(r.mem.offchip_reads, 0, "replica hits never leave chip");
        }
    }

    #[test]
    fn speculation_reruns_dram_heavy_batches_and_stays_exact() {
        // Cold LRU caches over large tables: every batch streams misses
        // to DRAM, so every speculative fork beyond batch 0 must fail
        // the zero-DRAM rule and replay serially — and the results must
        // still equal the serial loop exactly.
        let cfg = spec_cfg(OnchipPolicy::Cache(crate::config::CachePolicyKind::Lru), 2);
        let mut generator = TraceGenerator::new(&cfg.workload).unwrap();
        let traces: Vec<BatchTrace> =
            (0..4).map(|_| generator.next_batch()).collect();
        let refs: Vec<&BatchTrace> = traces.iter().collect();

        let mut spec = ShardedEmbeddingSim::new(&cfg);
        let windowed = spec.simulate_batches(&refs);
        assert!(spec.speculative_reruns() > 0, "DRAM-heavy batches must rerun");

        let mut serial = ShardedEmbeddingSim::new(&cfg);
        for (b, trace) in traces.iter().enumerate() {
            let want = serial.simulate_batch(trace);
            assert_sharded_eq(&windowed[b], &want, &format!("rerun batch {b}"));
        }
    }

    #[test]
    fn speculation_declines_on_unsafe_policies_and_multi_device() {
        // BRRIP keeps a cross-set fill counter: per-set merging is
        // unsound, so the window must fall back to the serial loop.
        let cfg = spec_cfg(OnchipPolicy::Cache(crate::config::CachePolicyKind::Brrip), 4);
        let sim = ShardedEmbeddingSim::new(&cfg);
        assert!(!sim.devices[0].speculation_safe());
        let mut generator = TraceGenerator::new(&cfg.workload).unwrap();
        let traces: Vec<BatchTrace> =
            (0..3).map(|_| generator.next_batch()).collect();
        let refs: Vec<&BatchTrace> = traces.iter().collect();
        let mut sim = ShardedEmbeddingSim::new(&cfg);
        sim.simulate_batches(&refs);
        assert_eq!(sim.speculative_commits() + sim.speculative_reruns(), 0);

        // multi-device runs use the per-device fan-out instead
        let mut mcfg = small_cfg(2, ShardStrategy::TableWise);
        mcfg.speculate_batches = 4;
        let mut msim = ShardedEmbeddingSim::new(&mcfg);
        msim.simulate_batches(&refs);
        assert_eq!(msim.speculative_commits() + msim.speculative_reruns(), 0);
    }
}

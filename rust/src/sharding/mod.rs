//! Multi-device sharded embedding simulation (skew-aware v2).
//!
//! Production DLRM serving shards its embedding tables across many NPU
//! devices (TensorDIMM-style placement): each device owns a shard in its
//! *own* memory system (local buffers + controller + HBM), gathers and
//! pools its share of every batch, and an all-to-all exchange
//! redistributes the pooled vectors to each sample's home device before
//! feature interaction. This module models exactly that:
//!
//! * [`TablePartitioner`] splits a [`BatchTrace`] across `N` devices —
//!   table-wise (whole tables round-robin), row-hashed (rows scattered
//!   by hash for load balance under per-table skew), or column-wise
//!   (every device gathers its `dim / N` slice of every lookup, so load
//!   balance is perfect and the exchange carries partial vectors);
//! * [`replicate::HotRowReplicator`] (installed via
//!   [`ShardedEmbeddingSim::set_replicas`]) pins the trace's top-K
//!   hottest rows on every device: lookups to them are rerouted to the
//!   sample's home device and served on-chip, costing no exchange and no
//!   off-chip read but pinning `K * vec_bytes` of each device's buffer;
//! * [`ShardedEmbeddingSim`] drives one persistent
//!   [`EmbeddingSim`] per device over its sub-trace, so cross-batch
//!   on-chip reuse is preserved per shard;
//! * an interconnect model charges the embedding-exchange phase from the
//!   busiest device's send volume over a configurable link bandwidth
//!   plus a fixed hop latency. Replica-served bags are produced at their
//!   home device and charge nothing.
//!
//! With one device (the preset default) the partitioner is the identity,
//! the exchange is free, replication is inert, and every result is
//! bit-identical to the classic single-NPU path. With replication off
//! and the serial exchange (the defaults), results are bit-identical to
//! the original table-sharded model.

pub mod replicate;

use crate::config::{ShardStrategy, SimConfig};
use crate::engine::embedding::EmbeddingSim;
use crate::mem::policy::pinning::PinSet;
use crate::stats::{DeviceCounters, MemCounts, OpCounts};
use crate::testutil::mix64;
use crate::trace::{BatchTrace, Lookup};
use replicate::HotRowReplicator;

/// One device's share of a batch: its lookups (in original issue order)
/// and the number of distinct bags it contributes pooled vectors to.
#[derive(Debug, Clone)]
pub struct DeviceTrace {
    pub trace: BatchTrace,
    /// Distinct `(sample, table)` bags this device holds (partial or
    /// complete) pooled results for — including replica-served bags.
    pub bags: u64,
    /// The subset of `bags` that must travel the all-to-all. Bag entries
    /// created only by replica-routed lookups live at the sample's home
    /// device already and are excluded. Equal to `bags` when no replica
    /// set is installed.
    pub exchange_bags: u64,
    /// Lookups routed here because their row is replicated on-device.
    pub replicated: u64,
}

/// Splits batch traces across devices according to a [`ShardStrategy`],
/// rerouting replicated hot rows to their sample's home device.
#[derive(Debug, Clone)]
pub struct TablePartitioner {
    devices: usize,
    strategy: ShardStrategy,
    /// Lookups per sample (tables * pool), for bag/home identification.
    lookups_per_sample: usize,
    replicas: HotRowReplicator,
}

impl TablePartitioner {
    pub fn new(devices: usize, strategy: ShardStrategy, lookups_per_sample: usize) -> Self {
        TablePartitioner {
            devices: devices.max(1),
            strategy,
            lookups_per_sample: lookups_per_sample.max(1),
            replicas: HotRowReplicator::empty(),
        }
    }

    /// Install the hot-row replica set used to reroute lookups.
    pub fn set_replicas(&mut self, replicas: HotRowReplicator) {
        self.replicas = replicas;
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Which device owns one (non-replicated) lookup. Column-wise
    /// sharding has no single owner — every device gathers a dim-slice —
    /// so [`split`](Self::split) places such lookups on all devices and
    /// this returns 0 only as a nominal anchor.
    #[inline]
    pub fn device_of(&self, lookup: &Lookup) -> usize {
        match self.strategy {
            ShardStrategy::TableWise => lookup.table as usize % self.devices,
            ShardStrategy::RowHashed => {
                (mix64(((lookup.table as u64) << 48) ^ lookup.row) % self.devices as u64) as usize
            }
            ShardStrategy::ColumnWise => 0,
        }
    }

    /// The device a sample's pooled bags are consumed on (feature
    /// interaction + top-MLP): samples round-robin across devices.
    #[inline]
    fn home_of(&self, lookup_index: usize) -> usize {
        (lookup_index / self.lookups_per_sample) % self.devices
    }

    /// Split one batch into per-device sub-traces, preserving the
    /// original issue order within each device. Under table/row sharding
    /// every lookup lands on exactly one device; under column-wise every
    /// non-replicated lookup lands on every device (each gathers its
    /// dim-slice). Replicated lookups always land only on the sample's
    /// home device.
    pub fn split(&self, trace: &BatchTrace) -> Vec<DeviceTrace> {
        let mut out = Vec::new();
        self.split_into(trace, &mut out);
        out
    }

    /// [`split`](Self::split) into a caller-owned buffer, reusing each
    /// device's `Vec<Lookup>` allocation across batches (the per-batch
    /// per-device allocations were a measurable share of sharded-run
    /// host time; the sharded engine feeds the same buffer every batch).
    pub fn split_into(&self, trace: &BatchTrace, out: &mut Vec<DeviceTrace>) {
        let cap_hint = match self.strategy {
            ShardStrategy::ColumnWise => trace.lookups.len(),
            _ => trace.lookups.len() / self.devices + 1,
        };
        self.reset_split(trace, out, cap_hint);
        match self.strategy {
            ShardStrategy::ColumnWise => self.split_column(trace, out),
            _ => self.split_owner(trace, out),
        }
    }

    /// Size `out` to `devices` entries with cleared counters and cleared
    /// (capacity-retaining) lookup buffers.
    fn reset_split(&self, trace: &BatchTrace, out: &mut Vec<DeviceTrace>, cap_hint: usize) {
        out.truncate(self.devices);
        while out.len() < self.devices {
            out.push(DeviceTrace {
                trace: BatchTrace {
                    batch_index: trace.batch_index,
                    lookups: Vec::with_capacity(cap_hint),
                },
                bags: 0,
                exchange_bags: 0,
                replicated: 0,
            });
        }
        for d in out.iter_mut() {
            d.trace.batch_index = trace.batch_index;
            d.trace.lookups.clear();
            d.bags = 0;
            d.exchange_bags = 0;
            d.replicated = 0;
        }
    }

    fn split_owner(&self, trace: &BatchTrace, out: &mut [DeviceTrace]) {
        // lookups are sample-major then table then pooling slot, so one
        // bag's lookups are contiguous: a device contributes to a bag
        // iff its last-seen bag id changes
        let mut last_bag: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_remote: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        for (i, l) in trace.lookups.iter().enumerate() {
            let replicated = !self.replicas.is_empty()
                && self.replicas.is_replicated(l.table, l.row);
            let d = if replicated { self.home_of(i) } else { self.device_of(l) };
            let bag = (i / self.lookups_per_sample, l.table);
            if last_bag[d] != Some(bag) {
                last_bag[d] = Some(bag);
                out[d].bags += 1;
            }
            if replicated {
                out[d].replicated += 1;
            } else if last_remote[d] != Some(bag) {
                // only non-replicated contributions travel the all-to-all
                last_remote[d] = Some(bag);
                out[d].exchange_bags += 1;
            }
            out[d].trace.lookups.push(*l);
        }
    }

    fn split_column(&self, trace: &BatchTrace, out: &mut [DeviceTrace]) {
        let mut last_bag: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        let mut last_remote: Vec<Option<(usize, u32)>> = vec![None; self.devices];
        for (i, l) in trace.lookups.iter().enumerate() {
            let bag = (i / self.lookups_per_sample, l.table);
            if !self.replicas.is_empty() && self.replicas.is_replicated(l.table, l.row) {
                // the home device holds the full replica: serve the whole
                // vector there, other devices skip this lookup entirely
                let d = self.home_of(i);
                if last_bag[d] != Some(bag) {
                    last_bag[d] = Some(bag);
                    out[d].bags += 1;
                }
                out[d].replicated += 1;
                out[d].trace.lookups.push(*l);
            } else {
                for d in 0..self.devices {
                    if last_bag[d] != Some(bag) {
                        last_bag[d] = Some(bag);
                        out[d].bags += 1;
                    }
                    if last_remote[d] != Some(bag) {
                        last_remote[d] = Some(bag);
                        out[d].exchange_bags += 1;
                    }
                    out[d].trace.lookups.push(*l);
                }
            }
        }
    }
}

/// Result of one batch's sharded embedding stage.
#[derive(Debug, Clone)]
pub struct ShardedStageResult {
    /// Embedding-stage wall cycles: the slowest device's gather+pool.
    pub cycles: u64,
    /// All-to-all exchange cycles charged after pooling (0 on 1 device).
    pub exchange_cycles: u64,
    /// Memory counters summed over devices.
    pub mem: MemCounts,
    /// Operation counters. Table/row sharding sums over devices; under
    /// column-wise the logical counts are reported (each lookup once,
    /// not once per dim-slice), so totals conserve against a 1-device
    /// run. `replicated_hits` is always the cross-device sum.
    pub ops: OpCounts,
    /// Per-device split of the same (physical per-device counts).
    pub per_device: Vec<DeviceCounters>,
}

/// Persistent multi-device embedding simulator: one [`EmbeddingSim`]
/// (local buffers, controller, DRAM state) per device plus the
/// partitioner and interconnect model.
pub struct ShardedEmbeddingSim {
    devices: Vec<EmbeddingSim>,
    partitioner: TablePartitioner,
    strategy: ShardStrategy,
    link_bytes_per_cycle: f64,
    hop_latency_cycles: u64,
    /// Bytes one device contributes per exchanged bag: the full pooled
    /// vector under table/row sharding, the device's dim-slice under
    /// column-wise (indexed by device).
    slice_bytes: Vec<u64>,
    /// Lines of one *full* embedding vector — what a replica hit costs
    /// on-chip, even on a device simulating only a dim-slice.
    full_vec_lines: u64,
    pool: usize,
    /// Host worker threads for the per-device fan-out (`[sim] threads`).
    /// The devices are fully independent state machines, so any value
    /// yields bit-identical results; `1` runs them serially in-line.
    threads: usize,
    /// Reused per-batch split buffer (device `Vec<Lookup>`s keep their
    /// capacity across batches instead of reallocating).
    split_buf: Vec<DeviceTrace>,
}

impl ShardedEmbeddingSim {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.sharding.devices.max(1);
        let emb = &cfg.workload.embedding;
        let strategy = cfg.sharding.strategy;
        // replicas pin on-chip capacity on every device (full vectors,
        // even under column-wise). Single-device runs stay untouched so
        // the classic path is bit-identical regardless of knobs.
        let reserve = if n > 1 {
            cfg.sharding.replicate_top_k as u64 * emb.vec_bytes()
        } else {
            0
        };
        let mut slice_bytes = Vec::with_capacity(n);
        let devices = (0..n)
            .map(|d| {
                let mut dev_cfg = cfg.clone();
                if reserve > 0 {
                    let m = &mut dev_cfg.hardware.mem;
                    m.onchip_bytes =
                        m.onchip_bytes.saturating_sub(reserve).max(m.access_granularity);
                }
                // a device's sub-trace carries only its shard's lookups
                // per sample — align the per-core sample stride to that:
                // exactly `owned_tables * pool` table-wise (tables are
                // assigned round-robin, so device d owns one extra table
                // when d < tables % n), ~`tables * pool / n` row-hashed,
                // and the full `tables * pool` column-wise (every device
                // sees every lookup, just a narrower slice of it)
                let per_sample = match strategy {
                    ShardStrategy::TableWise => {
                        let owned =
                            emb.num_tables / n + usize::from(d < emb.num_tables % n);
                        owned * emb.pool
                    }
                    ShardStrategy::RowHashed => emb.num_tables * emb.pool / n,
                    ShardStrategy::ColumnWise => {
                        let slice_dim =
                            (emb.dim / n + usize::from(d < emb.dim % n)).max(1);
                        dev_cfg.workload.embedding.dim = slice_dim;
                        emb.num_tables * emb.pool
                    }
                };
                slice_bytes.push(dev_cfg.workload.embedding.vec_bytes());
                let mut sim = EmbeddingSim::new(&dev_cfg);
                sim.set_lookups_per_sample(per_sample.max(1));
                sim
            })
            .collect();
        ShardedEmbeddingSim {
            devices,
            partitioner: TablePartitioner::new(n, strategy, emb.num_tables * emb.pool),
            strategy,
            link_bytes_per_cycle: cfg.sharding.link_bytes_per_cycle.max(f64::MIN_POSITIVE),
            hop_latency_cycles: cfg.sharding.hop_latency_cycles,
            slice_bytes,
            full_vec_lines: emb
                .vec_bytes()
                .div_ceil(cfg.hardware.mem.access_granularity)
                .max(1),
            pool: emb.pool,
            threads: cfg.threads.max(1),
            split_buf: Vec::new(),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Install the profiling-derived pin set on every device (the
    /// profile is workload-global; each shard pins its hot vectors).
    pub fn set_pin_set(&mut self, pins: PinSet) {
        for dev in &mut self.devices {
            dev.set_pin_set(pins.clone());
        }
    }

    /// Install the hot-row replica set on the partitioner (routing) and
    /// every device (on-chip service). No-op on a single device, which
    /// stays bit-identical to the classic path.
    pub fn set_replicas(&mut self, replicas: HotRowReplicator) {
        if self.devices.len() == 1 {
            return;
        }
        self.partitioner.set_replicas(replicas.clone());
        for dev in &mut self.devices {
            // replicas are stored whole, so a hit costs the full
            // vector's lines even on a dim-slice device
            dev.set_replicas(replicas.clone(), self.full_vec_lines);
        }
    }

    /// All-to-all cycles for per-device send volumes: the busiest
    /// device's outbound bytes over one link, plus a fixed hop latency.
    /// Each device keeps `1/N` of its pooled output local, so `N - 1` of
    /// `N` parts travel.
    fn exchange_cycles(&self, send_bytes: &[u64]) -> u64 {
        let max_bytes = send_bytes.iter().copied().max().unwrap_or(0);
        if max_bytes == 0 {
            return 0;
        }
        self.hop_latency_cycles + (max_bytes as f64 / self.link_bytes_per_cycle).ceil() as u64
    }

    /// Simulate one batch across all devices.
    pub fn simulate_batch(&mut self, trace: &BatchTrace) -> ShardedStageResult {
        let n = self.devices.len();
        if n == 1 {
            // single-device fast path: bit-identical to the classic
            // EmbeddingSim on the unsplit trace, exchange-free
            let r = self.devices[0].simulate_batch(trace);
            return ShardedStageResult {
                cycles: r.cycles,
                exchange_cycles: 0,
                mem: r.mem,
                ops: r.ops,
                per_device: vec![DeviceCounters {
                    device: 0,
                    cycles: r.cycles,
                    exchange_bytes: 0,
                    mem: r.mem,
                    ops: r.ops,
                }],
            };
        }

        // reuse the split buffer across batches (taken to keep the
        // borrow checker happy alongside `self.devices` below)
        let mut split = std::mem::take(&mut self.split_buf);
        self.partitioner.split_into(trace, &mut split);

        // Per-device fan-out: each device is a fully self-contained
        // state machine (its own buffers, controller, DRAM rows, cycle
        // cursor), so the N simulations are embarrassingly parallel.
        // Workers own contiguous device chunks and results come back in
        // device order, so the accumulation below is bit-identical to
        // the serial loop for any thread count.
        let workers = self.threads.min(n);
        let results: Vec<crate::engine::embedding::EmbeddingStageResult> = if workers > 1 {
            let chunk = n.div_ceil(workers);
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .devices
                    .chunks_mut(chunk)
                    .zip(split.chunks(chunk))
                    .map(|(sims, parts)| {
                        s.spawn(move || {
                            sims.iter_mut()
                                .zip(parts)
                                .map(|(sim, part)| {
                                    sim.simulate_batch_with_bags(&part.trace, part.bags)
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("device worker panicked"))
                    .collect()
            })
        } else {
            self.devices
                .iter_mut()
                .zip(&split)
                .map(|(sim, part)| sim.simulate_batch_with_bags(&part.trace, part.bags))
                .collect()
        };

        let mut mem = MemCounts::default();
        let mut ops = OpCounts::default();
        let mut per_device = Vec::with_capacity(n);
        let mut send_bytes = Vec::with_capacity(n);
        let mut wall = 0u64;
        for (device, (r, part)) in results.iter().zip(&split).enumerate() {
            // the partitioner knows the exact distinct-bag count of each
            // sub-trace (rerouted hot rows break pool alignment)
            wall = wall.max(r.cycles);
            mem.add(&r.mem);
            ops.add(&r.ops);
            // pooled output for the exchange-charged bags; (n-1)/n of it
            // is remote. Replica-served bags live at home: free.
            let bytes = part.exchange_bags * self.slice_bytes[device] * (n as u64 - 1)
                / n as u64;
            send_bytes.push(bytes);
            per_device.push(DeviceCounters {
                device,
                cycles: r.cycles,
                exchange_bytes: bytes,
                mem: r.mem,
                ops: r.ops,
            });
        }
        if matches!(self.strategy, ShardStrategy::ColumnWise) {
            // every device walked (its slice of) every lookup: report
            // logical op counts so totals conserve against one device,
            // keeping only the cross-device replica-hit sum
            let lookups = trace.lookups.len() as u64;
            let bags = lookups / self.pool.max(1) as u64;
            ops = OpCounts {
                macs: 0,
                // summing a bag of k vectors takes k - 1 adds
                vpu_ops: lookups.saturating_sub(bags),
                lookups,
                replicated_hits: per_device
                    .iter()
                    .map(|d| d.ops.replicated_hits)
                    .sum(),
            };
        }
        self.split_buf = split;
        ShardedStageResult {
            cycles: wall,
            exchange_cycles: self.exchange_cycles(&send_bytes),
            mem,
            ops,
            per_device,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, OnchipPolicy};
    use crate::mem::policy::pinning::Profile;
    use crate::trace::TraceGenerator;

    fn small_cfg(devices: usize, strategy: ShardStrategy) -> SimConfig {
        let mut cfg = presets::tpuv6e_dlrm_small();
        cfg.workload.batch_size = 32;
        cfg.workload.embedding.num_tables = 8;
        cfg.workload.embedding.rows_per_table = 20_000;
        cfg.workload.embedding.pool = 16;
        cfg.workload.trace.alpha = 1.1;
        cfg.hardware.mem.policy = OnchipPolicy::Spm;
        cfg.sharding.devices = devices;
        cfg.sharding.strategy = strategy;
        cfg
    }

    fn one_batch(cfg: &SimConfig) -> BatchTrace {
        TraceGenerator::new(&cfg.workload).unwrap().next_batch()
    }

    #[test]
    fn table_wise_assigns_whole_tables() {
        let p = TablePartitioner::new(4, ShardStrategy::TableWise, 128);
        for table in 0..16u32 {
            let d = p.device_of(&Lookup { table, row: 0 });
            assert_eq!(d, table as usize % 4);
            // rows never move a table-wise lookup
            assert_eq!(d, p.device_of(&Lookup { table, row: 12345 }));
        }
    }

    #[test]
    fn row_hashed_spreads_rows_of_one_table() {
        let p = TablePartitioner::new(4, ShardStrategy::RowHashed, 128);
        let mut seen = [false; 4];
        for row in 0..64 {
            seen[p.device_of(&Lookup { table: 0, row })] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 rows must touch all 4 devices");
    }

    #[test]
    fn split_conserves_and_preserves_order() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::RowHashed,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        // single linear merge walk: without replication each lookup's
        // device is a pure function of its value, so walking the original
        // trace once and advancing that device's cursor verifies both
        // placement and order (the old per-device `cursor.any` subsequence
        // scan was O(n²) and dominated the release suite's wall time)
        let mut cursors = vec![0usize; split.len()];
        for l in &trace.lookups {
            let d = p.device_of(l);
            assert_eq!(
                split[d].trace.lookups.get(cursors[d]),
                Some(l),
                "order violated for {l:?} on device {d}"
            );
            cursors[d] += 1;
        }
        for (d, dt) in split.iter().enumerate() {
            assert_eq!(cursors[d], dt.trace.lookups.len(), "device {d} fully consumed");
        }
    }

    #[test]
    fn table_wise_bag_count_is_owned_tables_times_batch() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::TableWise,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        // 8 tables over 4 devices = 2 tables each; every (sample, table)
        // bag is complete on its owner — and without replication every
        // bag travels the exchange
        for d in &split {
            assert_eq!(d.bags, 2 * cfg.workload.batch_size as u64);
            assert_eq!(d.exchange_bags, d.bags);
            assert_eq!(d.replicated, 0);
        }
    }

    #[test]
    fn column_split_places_every_lookup_on_every_device() {
        let cfg = small_cfg(4, ShardStrategy::ColumnWise);
        let trace = one_batch(&cfg);
        let p = TablePartitioner::new(
            4,
            ShardStrategy::ColumnWise,
            cfg.workload.embedding.num_tables * cfg.workload.embedding.pool,
        );
        let split = p.split(&trace);
        let bags = trace.lookups.len() as u64 / cfg.workload.embedding.pool as u64;
        for d in &split {
            assert_eq!(d.trace.lookups, trace.lookups, "full trace on each device");
            assert_eq!(d.bags, bags, "a slice of every bag on each device");
            assert_eq!(d.exchange_bags, bags);
        }
    }

    #[test]
    fn replicated_lookups_route_to_sample_home_device() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        // replicate this trace's own hottest rows
        let mut profile = Profile::new();
        for l in &trace.lookups {
            profile.record(l.table, l.row);
        }
        let replicas = replicate::HotRowReplicator::from_profile(&profile, 64);
        let mut p = TablePartitioner::new(4, ShardStrategy::TableWise, lps);
        p.set_replicas(replicas.clone());
        let split = p.split(&trace);
        // conservation: every lookup still lands exactly once
        let total: usize = split.iter().map(|d| d.trace.lookups.len()).sum();
        assert_eq!(total, trace.lookups.len());
        let replicated: u64 = split.iter().map(|d| d.replicated).sum();
        assert!(replicated > 0, "hot rows must reroute under a skewed trace");
        // a replicated lookup sits on its sample's home device, not its
        // table's owner; non-replicated lookups stay with their owner
        let mut expected: Vec<Vec<Lookup>> = vec![Vec::new(); 4];
        for (i, l) in trace.lookups.iter().enumerate() {
            let d = if replicas.is_replicated(l.table, l.row) {
                (i / lps) % 4 // sample's home device
            } else {
                l.table as usize % 4 // table-wise owner
            };
            expected[d].push(*l);
        }
        for (d, dt) in split.iter().enumerate() {
            assert_eq!(dt.trace.lookups, expected[d], "device {d} placement");
        }
        // exchange never grows under replication
        let plain = TablePartitioner::new(4, ShardStrategy::TableWise, lps).split(&trace);
        for (with, without) in split.iter().zip(&plain) {
            assert!(with.exchange_bags <= without.exchange_bags);
        }
    }

    #[test]
    fn single_device_is_bit_identical_to_embedding_sim() {
        let cfg = small_cfg(1, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let mut plain = EmbeddingSim::new(&cfg);
        let mut sharded = ShardedEmbeddingSim::new(&cfg);
        let a = plain.simulate_batch(&trace);
        let b = sharded.simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.mem, b.mem);
        assert_eq!(b.exchange_cycles, 0);
        assert_eq!(b.per_device.len(), 1);
    }

    #[test]
    fn counters_conserve_across_devices_under_spm() {
        // SPM streams every line off-chip, so per-device sums must equal
        // the 1-device run exactly, for both owner strategies
        for strategy in [ShardStrategy::TableWise, ShardStrategy::RowHashed] {
            let cfg1 = small_cfg(1, strategy);
            let trace = one_batch(&cfg1);
            let one = ShardedEmbeddingSim::new(&cfg1).simulate_batch(&trace);
            let cfg4 = small_cfg(4, strategy);
            let mut sim4 = ShardedEmbeddingSim::new(&cfg4);
            let four = sim4.simulate_batch(&trace);
            assert_eq!(four.mem.offchip_reads, one.mem.offchip_reads, "{strategy:?}");
            assert_eq!(four.mem.hits, one.mem.hits, "{strategy:?}");
            assert_eq!(four.ops.lookups, one.ops.lookups, "{strategy:?}");
            let dev_sum: u64 = four.per_device.iter().map(|d| d.mem.offchip_reads).sum();
            assert_eq!(dev_sum, one.mem.offchip_reads, "{strategy:?}");
        }
    }

    #[test]
    fn column_wise_conserves_logical_counters() {
        // dim 128 over 4 devices = 32-dim slices of 2 lines each: line
        // traffic and logical op counts match the 1-device run exactly
        let cfg1 = small_cfg(1, ShardStrategy::TableWise);
        let trace = one_batch(&cfg1);
        let one = ShardedEmbeddingSim::new(&cfg1).simulate_batch(&trace);
        let cfg4 = small_cfg(4, ShardStrategy::ColumnWise);
        let four = ShardedEmbeddingSim::new(&cfg4).simulate_batch(&trace);
        assert_eq!(four.mem.offchip_reads, one.mem.offchip_reads);
        assert_eq!(four.ops.lookups, one.ops.lookups);
        assert_eq!(four.ops.vpu_ops, one.ops.vpu_ops);
        // per-device: every device walked every lookup at a quarter dim
        for d in &four.per_device {
            assert_eq!(d.ops.lookups, one.ops.lookups);
            assert_eq!(d.mem.offchip_reads, one.mem.offchip_reads / 4);
        }
    }

    #[test]
    fn split_into_reuses_buffers_and_matches_split() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let lps = cfg.workload.embedding.num_tables * cfg.workload.embedding.pool;
        let mut gen = TraceGenerator::new(&cfg.workload).unwrap();
        let (t1, t2) = (gen.next_batch(), gen.next_batch());
        let p = TablePartitioner::new(4, ShardStrategy::RowHashed, lps);
        let mut buf = Vec::new();
        for t in [&t1, &t2] {
            // the reused buffer must match a fresh split exactly, with
            // stale counters/lookups from the previous batch cleared
            p.split_into(t, &mut buf);
            let fresh = p.split(t);
            assert_eq!(buf.len(), fresh.len());
            for (a, b) in buf.iter().zip(&fresh) {
                assert_eq!(a.trace.batch_index, t.batch_index);
                assert_eq!(a.trace.lookups, b.trace.lookups);
                assert_eq!(a.bags, b.bags);
                assert_eq!(a.exchange_bags, b.exchange_bags);
                assert_eq!(a.replicated, b.replicated);
            }
        }
    }

    #[test]
    fn threaded_fanout_is_bit_identical_to_serial() {
        // worker count is a pure host knob: every counter, per-device
        // split, and cycle total must be unchanged — including uneven
        // device/worker chunkings (4 devices over 3 workers)
        for strategy in [
            ShardStrategy::TableWise,
            ShardStrategy::RowHashed,
            ShardStrategy::ColumnWise,
        ] {
            let trace = one_batch(&small_cfg(4, strategy));
            let run = |threads: usize| {
                let mut cfg = small_cfg(4, strategy);
                cfg.threads = threads;
                let mut sim = ShardedEmbeddingSim::new(&cfg);
                // two batches so persistent per-device state is exercised
                let a = sim.simulate_batch(&trace);
                let b = sim.simulate_batch(&trace);
                (a, b)
            };
            let serial = run(1);
            for threads in [2usize, 3, 4, 16] {
                let parallel = run(threads);
                for ((s, p), which) in [(&serial.0, &parallel.0), (&serial.1, &parallel.1)]
                    .into_iter()
                    .zip(["first", "second"])
                {
                    assert_eq!(s.cycles, p.cycles, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.exchange_cycles, p.exchange_cycles, "{strategy:?} x{threads}");
                    assert_eq!(s.mem, p.mem, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.ops, p.ops, "{strategy:?} x{threads} {which}");
                    assert_eq!(s.per_device, p.per_device, "{strategy:?} x{threads} {which}");
                }
            }
        }
    }

    #[test]
    fn sharding_is_deterministic() {
        let cfg = small_cfg(4, ShardStrategy::RowHashed);
        let trace = one_batch(&cfg);
        let a = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        let b = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.exchange_cycles, b.exchange_cycles);
        assert_eq!(a.mem, b.mem);
    }

    #[test]
    fn more_devices_never_slow_the_embedding_stage() {
        let mut prev = u64::MAX;
        for devices in [1usize, 2, 4] {
            let cfg = small_cfg(devices, ShardStrategy::TableWise);
            let trace = one_batch(&cfg);
            let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
            assert!(
                r.cycles <= prev,
                "{devices} devices: {} cycles > previous {prev}",
                r.cycles
            );
            prev = r.cycles;
        }
    }

    #[test]
    fn exchange_positive_on_multi_device_and_scales_with_links() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let r = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);
        assert!(r.exchange_cycles > cfg.sharding.hop_latency_cycles);

        let mut fast = cfg.clone();
        fast.sharding.link_bytes_per_cycle *= 8.0;
        let rf = ShardedEmbeddingSim::new(&fast).simulate_batch(&trace);
        assert!(rf.exchange_cycles < r.exchange_cycles, "faster links must shrink exchange");
    }

    #[test]
    fn row_hashed_exchanges_more_than_table_wise() {
        // row-hashing leaves nearly every device with partials for
        // nearly every bag — the classic row-wise reduce cost
        let cfg_t = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg_t);
        let t = ShardedEmbeddingSim::new(&cfg_t).simulate_batch(&trace);
        let cfg_r = small_cfg(4, ShardStrategy::RowHashed);
        let r = ShardedEmbeddingSim::new(&cfg_r).simulate_batch(&trace);
        let sum = |x: &ShardedStageResult| -> u64 {
            x.per_device.iter().map(|d| d.exchange_bytes).sum()
        };
        assert!(sum(&r) > sum(&t), "row {} !> table {}", sum(&r), sum(&t));
    }

    #[test]
    fn replication_serves_hot_rows_on_chip_and_shrinks_exchange() {
        let cfg = small_cfg(4, ShardStrategy::TableWise);
        let trace = one_batch(&cfg);
        let plain = ShardedEmbeddingSim::new(&cfg).simulate_batch(&trace);

        let mut rcfg = cfg.clone();
        rcfg.sharding.replicate_top_k = 256;
        let mut sim = ShardedEmbeddingSim::new(&rcfg);
        sim.set_replicas(
            replicate::HotRowReplicator::from_workload(&rcfg.workload, 256).unwrap(),
        );
        let rep = sim.simulate_batch(&trace);
        assert!(rep.ops.replicated_hits > 0);
        assert_eq!(rep.ops.lookups, plain.ops.lookups, "lookups conserve");
        // replica hits convert off-chip lines to on-chip hits, 8 lines
        // per 128-dim vector
        assert_eq!(
            rep.mem.offchip_reads + rep.ops.replicated_hits * 8,
            plain.mem.offchip_reads
        );
        assert!(rep.exchange_cycles <= plain.exchange_cycles);
    }
}

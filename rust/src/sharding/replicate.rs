//! Hot-row replication for skew-aware sharding.
//!
//! Embedding traffic is Zipfian (paper §II): a small set of rows absorbs
//! a disproportionate share of lookups, and under table-wise sharding
//! those rows concentrate on whichever devices own the hot tables. The
//! classic production remedy (Neo/FBGEMM-style hierarchical placement)
//! is to *replicate* the hottest rows on every device: a lookup to a
//! replicated row is served at its sample's home device straight from
//! on-chip memory, which
//!
//! * spreads the Zipf head uniformly across devices (load balance),
//! * removes those rows' contribution to the all-to-all exchange, and
//! * costs on-chip capacity — the replicas are pinned on *every* device,
//!   shrinking the buffer available to caching/pinning policies.
//!
//! The replica set is derived from the trace's own empirical row
//! frequencies (the same deterministic regeneration the profiling-based
//! pinning policy uses), so it adapts to whatever skew the workload's
//! [`crate::trace::zipf::ZipfSampler`] (or a replayed trace file)
//! actually produces.

use crate::config::WorkloadConfig;
use crate::mem::policy::pinning::Profile;
use std::collections::BTreeSet;

/// The set of `(table, row)` pairs replicated on every device. An
/// ordered set: replica membership feeds per-device exchange accounting,
/// which must not depend on hash order.
#[derive(Debug, Clone, Default)]
pub struct HotRowReplicator {
    rows: BTreeSet<(u32, u64)>,
    k: usize,
}

impl HotRowReplicator {
    /// No replication (the default: `replicate_top_k = 0`).
    pub fn empty() -> Self {
        Self::default()
    }

    /// Replicate the `k` globally hottest rows of a frequency profile
    /// (ties broken deterministically by `(table, row)` id).
    pub fn from_profile(profile: &Profile, k: usize) -> Self {
        HotRowReplicator {
            rows: profile.top_k(k).into_iter().collect(),
            k,
        }
    }

    /// Profile the workload's own (deterministically regenerated) trace
    /// and replicate its `k` hottest rows.
    pub fn from_workload(workload: &WorkloadConfig, k: usize) -> anyhow::Result<Self> {
        if k == 0 {
            return Ok(Self::empty());
        }
        Ok(Self::from_profile(&Profile::from_workload(workload)?, k))
    }

    #[inline]
    pub fn is_replicated(&self, table: u32, row: u64) -> bool {
        self.rows.contains(&(table, row))
    }

    /// Rows actually replicated (≤ `k` when the trace touches fewer).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The configured top-K budget.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Sorted (ascending `(table, row)`) iterator over the replicated
    /// ids — merge-join input for [`crate::trace::BatchPlan`].
    pub fn iter(&self) -> impl Iterator<Item = &(u32, u64)> {
        self.rows.iter()
    }

    /// On-chip bytes the replica set pins on *each* device.
    pub fn pinned_bytes(&self, vec_bytes: u64) -> u64 {
        self.rows.len() as u64 * vec_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn profile_with(counts: &[((u32, u64), u64)]) -> Profile {
        let mut p = Profile::new();
        for &((t, r), c) in counts {
            for _ in 0..c {
                p.record(t, r);
            }
        }
        p
    }

    #[test]
    fn replicates_hottest_rows_only() {
        let p = profile_with(&[((0, 1), 9), ((0, 2), 5), ((1, 7), 3)]);
        let r = HotRowReplicator::from_profile(&p, 2);
        assert!(r.is_replicated(0, 1));
        assert!(r.is_replicated(0, 2));
        assert!(!r.is_replicated(1, 7));
        assert_eq!(r.len(), 2);
        assert_eq!(r.k(), 2);
    }

    #[test]
    fn footprint_bounded_by_touched_rows() {
        let p = profile_with(&[((0, 1), 1)]);
        let r = HotRowReplicator::from_profile(&p, 100);
        assert_eq!(r.len(), 1);
        assert_eq!(r.pinned_bytes(512), 512);
    }

    #[test]
    fn empty_replicator_matches_k_zero() {
        let w = presets::dlrm_rmc2_small(4);
        let r = HotRowReplicator::from_workload(&w, 0).unwrap();
        assert!(r.is_empty());
        assert!(!r.is_replicated(0, 0));
        assert_eq!(r.pinned_bytes(512), 0);
    }

    #[test]
    fn from_workload_is_deterministic() {
        let mut w = presets::dlrm_rmc2_small(8);
        w.embedding.num_tables = 3;
        w.embedding.rows_per_table = 10_000;
        w.embedding.pool = 8;
        w.num_batches = 1;
        w.trace.alpha = 1.2;
        let a = HotRowReplicator::from_workload(&w, 32).unwrap();
        let b = HotRowReplicator::from_workload(&w, 32).unwrap();
        assert_eq!(a.len(), b.len());
        assert!(a.len() <= 32);
        assert!(!a.is_empty(), "a skewed trace must surface hot rows");
        for t in 0..3u32 {
            for row in 0..10_000u64 {
                assert_eq!(a.is_replicated(t, row), b.is_replicated(t, row));
            }
        }
    }
}

//! Hierarchical interconnect topologies for multi-device serving.
//!
//! Real NPU pods are not one flat all-to-all: devices sit in *nodes*
//! joined by fast intra-node links (ICI/NVLink-class serdes, one link
//! per device) while nodes talk over a much slower inter-node fabric
//! (DCN/InfiniBand-class, one shared uplink per node). Embedding
//! exchange cost is dominated by which tier a pooled (or partial)
//! vector crosses — the all-to-all bottleneck TensorDIMM identifies for
//! embedding gathers. This module models exactly that split:
//!
//! * [`Topology`] — flat (one tier, the classic model, bit-identical to
//!   the pre-topology accounting) or two-tier
//!   `{nodes × devices_per_node}` with per-tier bandwidths. The
//!   exchange model consults it per device-pair: bags whose home device
//!   shares the sender's node ride the intra links, the rest cross the
//!   node uplink. The two phases are serialized (intra drain, then
//!   inter drain), and the inter tier charges the *busiest node's*
//!   aggregate uplink bytes — the uplink is a per-node resource shared
//!   by all of the node's devices, so packing hot shards into one node
//!   saturates it.
//! * [`TablePlacement`] — a [`crate::config::ShardStrategy`]-orthogonal
//!   placement pass for table-wise sharding: tables are assigned in
//!   descending (profiled) weight, each to the least-loaded node and
//!   then the least-loaded device inside it. The hottest tables land
//!   first, so they spread across nodes and pair with complementary
//!   cold tables within a node — minimizing the busiest node's
//!   inter-node exchange bytes (which is what the serialized inter-tier
//!   phase charges). Row-hashed and column-wise sharding are
//!   placement-invariant by construction (rows/slices are spread
//!   uniformly whatever the table→device map says), so the pass applies
//!   to table-wise splits only.

use crate::config::ShardingConfig;

/// Per-tier cycle split of one all-to-all exchange phase.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExchangeCycles {
    /// Full exchange phase: hop latency + intra drain + inter drain.
    pub total: u64,
    /// Intra-node transfer cycles (busiest device's intra bytes over
    /// one per-device link).
    pub intra: u64,
    /// Inter-node transfer cycles (busiest node's aggregate uplink
    /// bytes over one per-node link; 0 on flat topologies).
    pub inter: u64,
}

/// Interconnect shape: how `nodes * devices_per_node` devices are wired.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Topology {
    nodes: usize,
    devices_per_node: usize,
    intra_bytes_per_cycle: f64,
    inter_bytes_per_cycle: f64,
}

impl Topology {
    /// One flat all-to-all tier — the classic model. Every device pair
    /// is "intra", the inter tier never charges a cycle, and the
    /// exchange accounting is bit-identical to the pre-topology code.
    pub fn flat(devices: usize, link_bytes_per_cycle: f64) -> Self {
        Topology {
            nodes: 1,
            devices_per_node: devices.max(1),
            intra_bytes_per_cycle: link_bytes_per_cycle.max(f64::MIN_POSITIVE),
            inter_bytes_per_cycle: link_bytes_per_cycle.max(f64::MIN_POSITIVE),
        }
    }

    /// Two tiers: `nodes` nodes of `devices_per_node` devices each.
    pub fn two_tier(
        nodes: usize,
        devices_per_node: usize,
        intra_bytes_per_cycle: f64,
        inter_bytes_per_cycle: f64,
    ) -> Self {
        Topology {
            nodes: nodes.max(1),
            devices_per_node: devices_per_node.max(1),
            intra_bytes_per_cycle: intra_bytes_per_cycle.max(f64::MIN_POSITIVE),
            inter_bytes_per_cycle: inter_bytes_per_cycle.max(f64::MIN_POSITIVE),
        }
    }

    /// Resolve the configured topology for a sharding deployment.
    /// `nodes = 1` (the default) is flat and always uses the classic
    /// `sharding.link_bytes_per_cycle`, so every pre-topology config
    /// stays bit-identical no matter what the other `[topology]` keys
    /// say. Two-tier intra bandwidth falls back to the flat link when
    /// not set explicitly.
    pub fn from_config(s: &ShardingConfig) -> Self {
        let devices = s.devices.max(1);
        let nodes = s.topology.nodes.max(1);
        if nodes <= 1 || devices <= 1 {
            Topology::flat(devices, s.link_bytes_per_cycle)
        } else {
            // validate() rejects non-divisible counts on every real
            // path; ceil keeps node_of in range even on raw configs
            Topology::two_tier(
                nodes,
                devices.div_ceil(nodes),
                s.topology
                    .intra_link_bytes_per_cycle
                    .unwrap_or(s.link_bytes_per_cycle),
                s.topology.inter_link_bytes_per_cycle,
            )
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn devices_per_node(&self) -> usize {
        self.devices_per_node
    }

    pub fn devices(&self) -> usize {
        self.nodes * self.devices_per_node
    }

    pub fn is_flat(&self) -> bool {
        self.nodes == 1
    }

    /// Which node a device belongs to (devices are numbered node-major:
    /// node `k` owns devices `k*dpn .. (k+1)*dpn`).
    #[inline]
    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    /// The node's designated leader device (its first device) — where
    /// per-node hot-row replicas live.
    #[inline]
    pub fn leader_of(&self, node: usize) -> usize {
        node * self.devices_per_node
    }

    /// Whether a device is its node's leader.
    #[inline]
    pub fn is_leader(&self, device: usize) -> bool {
        device % self.devices_per_node == 0
    }

    #[inline]
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Cycles for one exchange phase given the busiest device's
    /// intra-tier bytes and the busiest node's aggregate inter-tier
    /// bytes. The two tier drains are serialized after one hop launch;
    /// an exchange with no bytes at all is free (no hop either),
    /// matching the classic accounting.
    pub fn exchange_cycles(
        &self,
        hop_latency_cycles: u64,
        intra_max_bytes: u64,
        inter_max_bytes: u64,
    ) -> ExchangeCycles {
        if intra_max_bytes == 0 && inter_max_bytes == 0 {
            return ExchangeCycles::default();
        }
        let drain = |bytes: u64, bpc: f64| -> u64 {
            if bytes == 0 {
                0
            } else {
                (bytes as f64 / bpc).ceil() as u64
            }
        };
        let intra = drain(intra_max_bytes, self.intra_bytes_per_cycle);
        let inter = drain(inter_max_bytes, self.inter_bytes_per_cycle);
        ExchangeCycles { total: hop_latency_cycles + intra + inter, intra, inter }
    }
}

/// An explicit table → device map for table-wise sharding, replacing
/// the legacy `table % devices` round-robin when node-aware placement
/// is enabled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TablePlacement {
    map: Vec<usize>,
    devices: usize,
}

impl TablePlacement {
    /// The legacy round-robin assignment, as an explicit map.
    pub fn round_robin(num_tables: usize, devices: usize) -> Self {
        let devices = devices.max(1);
        TablePlacement {
            map: (0..num_tables).map(|t| t % devices).collect(),
            devices,
        }
    }

    /// Greedy node-aware balance: tables in descending weight order
    /// (ties by table id) each go to the least-loaded node, then the
    /// least-loaded device within it (ties by lowest id). Zero-weight
    /// tables count as weight 1 so uniform workloads still spread.
    /// Deterministic for a given weight vector and topology.
    pub fn balance(weights: &[u64], topo: &Topology) -> Self {
        let nodes = topo.nodes();
        let dpn = topo.devices_per_node();
        let mut node_load = vec![0u64; nodes];
        let mut dev_load = vec![0u64; topo.devices()];
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_unstable_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
        let mut map = vec![0usize; weights.len()];
        for t in order {
            let w = weights[t].max(1);
            let node = (0..nodes)
                .min_by_key(|&k| (node_load[k], k))
                .expect("at least one node");
            let first = topo.leader_of(node);
            let dev = (first..first + dpn)
                .min_by_key(|&d| (dev_load[d], d))
                .expect("at least one device per node");
            map[t] = dev;
            node_load[node] += w;
            dev_load[dev] += w;
        }
        TablePlacement { map, devices: topo.devices() }
    }

    /// The device a table is placed on (tables beyond the map — which a
    /// well-formed trace never produces — fall back to round-robin).
    #[inline]
    pub fn device_of(&self, table: u32) -> usize {
        self.map
            .get(table as usize)
            .copied()
            .unwrap_or(table as usize % self.devices)
    }

    /// How many tables a device owns under this placement.
    pub fn tables_on(&self, device: usize) -> usize {
        self.map.iter().filter(|&&d| d == device).count()
    }

    pub fn num_tables(&self) -> usize {
        self.map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ShardingConfig, TopologyConfig};

    #[test]
    fn node_arithmetic() {
        let t = Topology::two_tier(2, 4, 100.0, 12.5);
        assert_eq!(t.devices(), 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.leader_of(1), 4);
        assert!(t.is_leader(0) && t.is_leader(4));
        assert!(!t.is_leader(5));
        assert!(t.same_node(1, 3));
        assert!(!t.same_node(3, 4));
        assert!(!t.is_flat());
    }

    #[test]
    fn flat_exchange_matches_legacy_formula() {
        // the classic model: hop + ceil(max_bytes / link), 0 when idle
        let t = Topology::flat(4, 100.0);
        assert!(t.is_flat());
        let ex = t.exchange_cycles(700, 28_672, 0);
        assert_eq!(ex.total, 700 + (28_672f64 / 100.0).ceil() as u64);
        assert_eq!(ex.intra, ex.total - 700);
        assert_eq!(ex.inter, 0);
        assert_eq!(t.exchange_cycles(700, 0, 0), ExchangeCycles::default());
    }

    #[test]
    fn two_tier_exchange_serializes_tiers() {
        let t = Topology::two_tier(2, 4, 100.0, 25.0);
        let ex = t.exchange_cycles(700, 1000, 1000);
        assert_eq!(ex.intra, 10);
        assert_eq!(ex.inter, 40, "inter tier drains over the slower uplink");
        assert_eq!(ex.total, 700 + 10 + 40);
        // inter-only traffic still pays the hop
        let ex = t.exchange_cycles(700, 0, 500);
        assert_eq!(ex, ExchangeCycles { total: 720, intra: 0, inter: 20 });
    }

    #[test]
    fn from_config_defaults_to_flat_and_ignores_tier_knobs_at_one_node() {
        // weird tier settings must be inert while nodes = 1
        let s = ShardingConfig {
            devices: 4,
            topology: TopologyConfig {
                intra_link_bytes_per_cycle: Some(3.0),
                inter_link_bytes_per_cycle: 1.0,
                ..TopologyConfig::default()
            },
            ..ShardingConfig::default()
        };
        let t = Topology::from_config(&s);
        assert!(t.is_flat());
        assert_eq!(
            t.exchange_cycles(700, 10_000, 0),
            Topology::flat(4, s.link_bytes_per_cycle).exchange_cycles(700, 10_000, 0)
        );
    }

    #[test]
    fn from_config_two_tier_inherits_flat_link_for_intra() {
        let s = ShardingConfig {
            devices: 8,
            link_bytes_per_cycle: 64.0,
            topology: TopologyConfig {
                nodes: 2,
                inter_link_bytes_per_cycle: 8.0,
                ..TopologyConfig::default()
            },
            ..ShardingConfig::default()
        };
        let t = Topology::from_config(&s);
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.devices_per_node(), 4);
        // intra defaulted to the flat link bandwidth (64 B/cycle)
        assert_eq!(t.exchange_cycles(0, 640, 0).intra, 10);
        assert_eq!(t.exchange_cycles(0, 0, 640).inter, 80);
    }

    #[test]
    fn round_robin_matches_modulo() {
        let p = TablePlacement::round_robin(10, 4);
        for t in 0..10u32 {
            assert_eq!(p.device_of(t), t as usize % 4);
        }
        assert_eq!(p.tables_on(0), 3);
        assert_eq!(p.tables_on(3), 2);
    }

    #[test]
    fn balance_splits_lumpy_tables_across_nodes() {
        // 10 uniform tables on 2×4: round-robin packs 6 into node 0
        // (devices 0..3 own tables 0,8,1,9,2,3); the balanced placement
        // splits them 5/5
        let topo = Topology::two_tier(2, 4, 100.0, 12.5);
        let rr = TablePlacement::round_robin(10, 8);
        let rr_node0: usize = (0..4).map(|d| rr.tables_on(d)).sum();
        assert_eq!(rr_node0, 6, "round-robin is node-lumpy");
        let p = TablePlacement::balance(&[1; 10], &topo);
        let node0: usize = (0..4).map(|d| p.tables_on(d)).sum();
        let node1: usize = (4..8).map(|d| p.tables_on(d)).sum();
        assert_eq!((node0, node1), (5, 5), "balanced across nodes");
        // every table placed exactly once, no device over ceil(10/8)+1
        let total: usize = (0..8).map(|d| p.tables_on(d)).sum();
        assert_eq!(total, 10);
        assert!((0..8).all(|d| p.tables_on(d) <= 2));
    }

    #[test]
    fn balance_spreads_hot_tables_and_pairs_them_with_cold() {
        // two hot tables must not share a node; each co-locates with a
        // cold partner instead
        let topo = Topology::two_tier(2, 2, 100.0, 12.5);
        let p = TablePlacement::balance(&[100, 100, 1, 1], &topo);
        assert_ne!(
            topo.node_of(p.device_of(0)),
            topo.node_of(p.device_of(1)),
            "hot tables split across nodes"
        );
        // each node carries one hot + one cold table
        for node in 0..2 {
            let tables: Vec<u32> = (0..4u32)
                .filter(|&t| topo.node_of(p.device_of(t)) == node)
                .collect();
            assert_eq!(tables.len(), 2, "node {node}: {tables:?}");
            assert!(tables.iter().any(|&t| t < 2), "node {node} has a hot table");
            assert!(tables.iter().any(|&t| t >= 2), "node {node} has a cold table");
        }
    }

    #[test]
    fn balance_is_deterministic() {
        let topo = Topology::two_tier(2, 4, 100.0, 12.5);
        let w = [7u64, 3, 3, 9, 1, 1, 4, 4, 2, 2];
        assert_eq!(TablePlacement::balance(&w, &topo), TablePlacement::balance(&w, &topo));
    }
}

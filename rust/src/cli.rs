//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `eonsim <command> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

/// Parsed command line: a command word + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> anyhow::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(arg) = it.next() {
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("unexpected positional argument `{arg}`"))?
                .to_string();
            // `--key=value` or `--key value` or bare switch
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name, it.next().unwrap());
            } else {
                switches.push(name);
            }
        }
        Ok(Args { command, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad integer `{v}`: {e}")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad number `{v}`: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse(&["run", "--batch", "64", "--policy=lru", "--full"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.flag("batch"), Some("64"));
        assert_eq!(a.flag("policy"), Some("lru"));
        assert!(a.has("full"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn typed_flags() {
        let a = parse(&["run", "--batch", "64", "--alpha", "1.25"]);
        assert_eq!(a.usize_flag("batch", 1).unwrap(), 64);
        assert_eq!(a.usize_flag("other", 7).unwrap(), 7);
        assert_eq!(a.f64_flag("alpha", 0.0).unwrap(), 1.25);
        assert!(a.usize_flag("alpha", 0).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]);
        assert_eq!(a.command, "help");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["run".to_string(), "stray".to_string()]).is_err());
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = parse(&["x", "--full", "--batch", "8"]);
        assert!(a.has("full"));
        assert_eq!(a.flag("batch"), Some("8"));
    }
}

//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `eonsim <command> [positional]... [--flag value]... [--switch]...`
//!
//! Positionals (non-`--` words that no flag claimed as its value) are
//! collected in order for subcommand-style grammars like
//! `eonsim bench cmp OLD.json NEW.json`; commands that take none reject
//! them at dispatch time with a clear error.

use std::collections::BTreeMap;

/// Parsed command line: a command word + positionals + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> anyhow::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positionals = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                positionals.push(arg);
                continue;
            };
            // `--key=value` or `--key value` or bare switch
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if let Some(value) = it.next_if(|n| !n.starts_with("--")) {
                flags.insert(name.to_string(), value);
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args { command, flags, switches, positionals })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The `i`-th positional argument, if present.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad integer `{v}`: {e}")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad number `{v}`: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse(&["run", "--batch", "64", "--policy=lru", "--full"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.flag("batch"), Some("64"));
        assert_eq!(a.flag("policy"), Some("lru"));
        assert!(a.has("full"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn typed_flags() {
        let a = parse(&["run", "--batch", "64", "--alpha", "1.25"]);
        assert_eq!(a.usize_flag("batch", 1).unwrap(), 64);
        assert_eq!(a.usize_flag("other", 7).unwrap(), 7);
        assert_eq!(a.f64_flag("alpha", 0.0).unwrap(), 1.25);
        assert!(a.usize_flag("alpha", 0).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]);
        assert_eq!(a.command, "help");
    }

    #[test]
    fn collects_positionals_in_order() {
        let a = parse(&["bench", "cmp", "old.json", "new.json", "--fail-above", "5"]);
        assert_eq!(a.command, "bench");
        assert_eq!(a.positional(0), Some("cmp"));
        assert_eq!(a.positional(1), Some("old.json"));
        assert_eq!(a.positional(2), Some("new.json"));
        assert_eq!(a.positional(3), None);
        assert_eq!(a.flag("fail-above"), Some("5"));
        // flag values are claimed by their flag, not collected
        assert_eq!(a.positionals().len(), 3);
        assert!(parse(&["run"]).positionals().is_empty());
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = parse(&["x", "--full", "--batch", "8"]);
        assert!(a.has("full"));
        assert_eq!(a.flag("batch"), Some("8"));
    }
}

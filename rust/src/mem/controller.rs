//! NPU memory controller: bounded in-flight window + FR-FCFS scheduling
//! in front of the DRAM model (the structure the paper adopts from
//! mNPUsim's controller + DRAMSim3 backend).
//!
//! FR-FCFS ("first-ready, first-come-first-served") prefers requests that
//! hit an open row over older requests that would need an
//! activate/precharge, which is exactly what makes skewed embedding
//! streams faster than uniform ones off-chip.

use crate::config::DramConfig;
use crate::mem::dram::DramModel;

/// One scheduled request's completion.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub addr: u64,
    pub done_at: u64,
}

/// One pending request with its address mapping precomputed at enqueue —
/// the FR-FCFS scan must not re-derive (bank, row) per candidate per
/// issue (that was the simulator's top bottleneck; EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy)]
struct Pending {
    addr: u64,
    arrival: u64,
    bank: u32,
    row: u64,
}

/// FR-FCFS memory controller with a bounded reorder window.
///
/// The window Vec is kept in age order (push_back; remove-at-index), so
/// "oldest" is index 0 and the row-hit scan can early-exit at the first
/// hit — with embedding vectors spanning 8 consecutive lines, the open
/// row usually matches within the first few entries (§Perf iteration 3).
#[derive(Clone)]
pub struct MemController {
    dram: DramModel,
    window: Vec<Pending>,
    window_cap: usize,
    issued: u64,
    last_done: u64,
}

impl MemController {
    /// `bytes_per_cycle`: aggregate off-chip bandwidth in bytes per core
    /// cycle (forwarded to [`DramModel`]).
    pub fn new(cfg: &DramConfig, line_bytes: u64, bytes_per_cycle: f64, window_cap: usize) -> Self {
        MemController {
            dram: DramModel::new(cfg, line_bytes, bytes_per_cycle),
            window: Vec::with_capacity(window_cap),
            window_cap: window_cap.max(1),
            issued: 0,
            last_done: 0,
        }
    }

    /// Enqueue a line read arriving at `arrival`. If the window is full,
    /// the best candidate is issued first. Returns the completion of any
    /// request this call had to retire to make space.
    pub fn enqueue(&mut self, addr: u64, arrival: u64) -> Option<Completion> {
        let mut retired = None;
        if self.window.len() == self.window_cap {
            retired = Some(self.issue_best());
        }
        let (_, bank, row) = self.dram.map(addr);
        self.window.push(Pending { addr, arrival, bank: bank as u32, row });
        retired
    }

    /// Issue everything still pending, in FR-FCFS order; returns the
    /// completions in issue order.
    pub fn drain(&mut self) -> Vec<Completion> {
        let mut out = Vec::with_capacity(self.window.len());
        while !self.window.is_empty() {
            out.push(self.issue_best());
        }
        out
    }

    /// Pick the FR-FCFS winner: oldest row-hit if any, else oldest.
    /// The window is age-ordered, so the scan early-exits at the first
    /// row-hit and falls back to index 0 (the oldest) otherwise.
    fn issue_best(&mut self) -> Completion {
        debug_assert!(!self.window.is_empty());
        let mut pick = 0usize;
        for (i, p) in self.window.iter().enumerate() {
            if self.dram.is_row_open(p.bank as usize, p.row) {
                pick = i;
                break;
            }
        }
        // Vec::remove keeps age order; the memmove is cheap (window is
        // a few hundred bytes, contiguous) — a VecDeque variant measured
        // *slower* due to non-contiguous scan (EXPERIMENTS.md §Perf it.4)
        let p = self.window.remove(pick);
        let done_at = self.dram.access(p.addr, p.arrival);
        self.issued += 1;
        self.last_done = self.last_done.max(done_at);
        Completion { addr: p.addr, done_at }
    }

    /// Cycle at which the last issued request completed.
    pub fn last_done(&self) -> u64 {
        self.last_done
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }

    pub fn dram(&self) -> &DramModel {
        &self.dram
    }

    pub fn pending(&self) -> usize {
        self.window.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ctrl(window: usize) -> MemController {
        MemController::new(&presets::tpuv6e_hardware().mem.dram, 64, 1700.0, window)
    }

    #[test]
    fn drain_completes_everything() {
        let mut c = ctrl(8);
        for i in 0..20u64 {
            c.enqueue(i * 64, 0);
        }
        let mut done = c.issued();
        done += c.drain().len() as u64;
        assert_eq!(done, 20);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn window_overflow_retires_oldest_class() {
        let mut c = ctrl(2);
        assert!(c.enqueue(0, 0).is_none());
        assert!(c.enqueue(64, 0).is_none());
        assert!(c.enqueue(128, 0).is_some(), "third enqueue spills one");
    }

    #[test]
    fn fr_fcfs_prefers_row_hits() {
        // Two requests to bank B (different rows) + one row-hit to the
        // open row: the row-hit should complete earlier than FIFO order
        // would allow.
        let cfg = presets::tpuv6e_hardware().mem.dram;
        let probe = DramModel::new(&cfg, 64, 1700.0);
        let (_, bank0, row0) = probe.map(0);
        // same bank different row
        let mut conflict = None;
        let mut samerow = None;
        for i in 1..1_000_000u64 {
            let a = i * 64;
            let (_, b, r) = probe.map(a);
            if b == bank0 && r != row0 && conflict.is_none() {
                conflict = Some(a);
            }
            if b == bank0 && r == row0 && a != 0 && samerow.is_none() {
                samerow = Some(a);
            }
            if conflict.is_some() && samerow.is_some() {
                break;
            }
        }
        let (conflict, samerow) = (conflict.unwrap(), samerow.unwrap());

        let mut c = MemController::new(&cfg, 64, 1700.0, 8);
        c.enqueue(0, 0); // opens row0
        let first = c.drain(); // row0 now open
        assert_eq!(first.len(), 1);
        // enqueue conflict first, then row-hit; FR-FCFS issues row-hit first
        c.enqueue(conflict, 0);
        c.enqueue(samerow, 0);
        let done = c.drain();
        assert_eq!(done[0].addr, samerow, "row-hit bypasses older conflict");
        assert_eq!(done[1].addr, conflict);
    }

    #[test]
    fn last_done_monotone() {
        let mut c = ctrl(4);
        for i in 0..50u64 {
            c.enqueue(i * 64 * 97, i);
        }
        c.drain();
        assert!(c.last_done() > 0);
        assert_eq!(c.issued(), 50);
    }
}

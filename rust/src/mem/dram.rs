//! DRAMSim3-lite off-chip memory model (DESIGN.md §3 substitution for the
//! DRAMSim3 backend mNPUsim uses).
//!
//! Models the first-order HBM behaviour embedding traffic is sensitive
//! to: channel parallelism, per-bank row-buffer state (open-page policy),
//! ACT/PRE/CAS timing, and data-bus serialization per channel. Addresses
//! are interleaved `channel -> bank -> row` at line granularity, the
//! standard fine-grained interleave for HBM-class parts.

use crate::config::DramConfig;

/// Per-bank state: open row + ready cycle.
#[derive(Debug, Clone, Copy)]
struct Bank {
    open_row: u64,
    ready_at: u64,
}

const NO_ROW: u64 = u64::MAX;

/// Precomputed shifts for the pow2 address-mapping fast path.
#[derive(Debug, Clone, Copy)]
struct MapShifts {
    line_shift: u32,
    chan_mask: u64,
    chan_shift: u32,
    row_line_shift: u32,
    bank_mask: u64,
    bank_shift: u32,
}

/// Outcome detail for one DRAM access (for stats and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    Hit,
    Miss,
    Conflict,
}

/// Cycle-level DRAM device + channel bus model.
///
/// Bank timing (ACT/PRE/CAS) is integral in core cycles; the per-channel
/// data-bus occupancy is fractional so the aggregate bandwidth exactly
/// matches the configured `bandwidth_bytes_per_sec` (one 64 B line at
/// 100 GB/s-per-channel occupies ~0.6 core cycles — rounding that up per
/// access would understate HBM bandwidth by ~3x).
#[derive(Clone)]
pub struct DramModel {
    cfg: DramConfig,
    line_bytes: u64,
    /// Data-bus cycles one line burst occupies on its channel.
    burst_cycles: f64,
    /// Shift/mask fast path for the address mapping when every geometry
    /// parameter is a power of two (the common case); None -> div/mod.
    shifts: Option<MapShifts>,
    banks: Vec<Bank>, // channels x banks_per_channel
    bus_ready: Vec<f64>, // per channel, fractional cycles
    row_hits: u64,
    row_misses: u64,
    row_conflicts: u64,
    reads: u64,
}

impl DramModel {
    /// `bytes_per_cycle`: aggregate off-chip bandwidth in bytes per core
    /// cycle (`HardwareConfig::dram_bytes_per_cycle`).
    pub fn new(cfg: &DramConfig, line_bytes: u64, bytes_per_cycle: f64) -> Self {
        let nbanks = cfg.channels * cfg.banks_per_channel;
        let per_channel = bytes_per_cycle / cfg.channels as f64;
        let burst_cycles = line_bytes as f64 / per_channel;
        let lines_per_row = (cfg.row_bytes / line_bytes).max(1);
        let shifts = if line_bytes.is_power_of_two()
            && (cfg.channels as u64).is_power_of_two()
            && lines_per_row.is_power_of_two()
            && (cfg.banks_per_channel as u64).is_power_of_two()
        {
            Some(MapShifts {
                line_shift: line_bytes.trailing_zeros(),
                // eonsim-lint: allow(underflow, reason = "the is_power_of_two guard above rejects 0, so channels >= 1 and the mask cannot wrap")
                chan_mask: cfg.channels as u64 - 1,
                chan_shift: (cfg.channels as u64).trailing_zeros(),
                row_line_shift: lines_per_row.trailing_zeros(),
                // eonsim-lint: allow(underflow, reason = "the is_power_of_two guard above rejects 0, so banks_per_channel >= 1 and the mask cannot wrap")
                bank_mask: cfg.banks_per_channel as u64 - 1,
                bank_shift: (cfg.banks_per_channel as u64).trailing_zeros(),
            })
        } else {
            None
        };
        DramModel {
            cfg: cfg.clone(),
            line_bytes,
            burst_cycles,
            shifts,
            banks: vec![Bank { open_row: NO_ROW, ready_at: 0 }; nbanks],
            bus_ready: vec![0.0; cfg.channels],
            row_hits: 0,
            row_misses: 0,
            row_conflicts: 0,
            reads: 0,
        }
    }

    /// Map a byte address to (channel, bank index within model, row).
    #[inline]
    pub fn map(&self, addr: u64) -> (usize, usize, u64) {
        if let Some(sh) = self.shifts {
            // pow2 fast path: pure shifts and masks (EXPERIMENTS.md §Perf)
            let line = addr >> sh.line_shift;
            let channel = (line & sh.chan_mask) as usize;
            let row_global = (line >> sh.chan_shift) >> sh.row_line_shift;
            let bank_in_ch = (row_global & sh.bank_mask) as usize;
            let row = row_global >> sh.bank_shift;
            return (channel, channel * self.cfg.banks_per_channel + bank_in_ch, row);
        }
        let line = addr / self.line_bytes;
        let channel = (line % self.cfg.channels as u64) as usize;
        let line_in_ch = line / self.cfg.channels as u64;
        let lines_per_row = (self.cfg.row_bytes / self.line_bytes).max(1);
        let row_global = line_in_ch / lines_per_row;
        let bank_in_ch = (row_global % self.cfg.banks_per_channel as u64) as usize;
        let row = row_global / self.cfg.banks_per_channel as u64;
        (channel, channel * self.cfg.banks_per_channel + bank_in_ch, row)
    }

    /// Issue one line read arriving at `arrival`; returns the data-ready
    /// cycle. Open-page policy: rows stay open until a conflict.
    pub fn access(&mut self, addr: u64, arrival: u64) -> u64 {
        let (channel, bank_idx, row) = self.map(addr);
        let t = &self.cfg.timing;
        let bank = &mut self.banks[bank_idx];
        self.reads += 1;

        let start = arrival.max(bank.ready_at);
        let (ready, outcome) = if bank.open_row == row {
            (start + t.t_cas, RowOutcome::Hit)
        } else if bank.open_row == NO_ROW {
            (start + t.t_rcd + t.t_cas, RowOutcome::Miss)
        } else {
            (start + t.t_rp + t.t_rcd + t.t_cas, RowOutcome::Conflict)
        };
        match outcome {
            RowOutcome::Hit => self.row_hits += 1,
            RowOutcome::Miss => self.row_misses += 1,
            RowOutcome::Conflict => self.row_conflicts += 1,
        }
        bank.open_row = row;
        // bank can accept the next column command after tCCD (or the full
        // cycle for activates — approximated by ready)
        bank.ready_at = start + t.t_ccd;

        // serialize the burst on the channel data bus (fractional cycles)
        let bus = &mut self.bus_ready[channel];
        let data_start = (ready as f64).max(*bus);
        *bus = data_start + self.burst_cycles;
        (data_start + self.burst_cycles).ceil() as u64
    }

    /// Whether `row` is currently open in bank `bank_idx` (used by the
    /// FR-FCFS controller to pick first-ready requests).
    #[inline]
    pub fn is_row_open(&self, bank_idx: usize, row: u64) -> bool {
        self.banks[bank_idx].open_row == row
    }

    pub fn row_hits(&self) -> u64 {
        self.row_hits
    }

    pub fn row_misses(&self) -> u64 {
        self.row_misses
    }

    pub fn row_conflicts(&self) -> u64 {
        self.row_conflicts
    }

    pub fn reads(&self) -> u64 {
        self.reads
    }

    /// Peak lines per cycle across all channels (roofline for tests).
    pub fn peak_lines_per_cycle(&self) -> f64 {
        self.cfg.channels as f64 / self.burst_cycles
    }

    pub fn reset_stats(&mut self) {
        self.row_hits = 0;
        self.row_misses = 0;
        self.row_conflicts = 0;
        self.reads = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn model() -> DramModel {
        DramModel::new(&presets::tpuv6e_hardware().mem.dram, 64, 1700.0)
    }

    #[test]
    fn sequential_same_row_hits() {
        let mut m = model();
        // lines within one row on one channel: stride = channels*line
        let stride = 16 * 64u64;
        m.access(0, 0);
        let mut prev = 0;
        for i in 1..8u64 {
            let done = m.access(i * stride % (1024 / 64 * stride), prev);
            prev = done;
        }
        assert!(m.row_hits() > 0);
    }

    #[test]
    fn first_access_is_row_miss() {
        let mut m = model();
        m.access(0, 0);
        assert_eq!(m.row_misses(), 1);
        assert_eq!(m.row_hits() + m.row_conflicts(), 0);
    }

    #[test]
    fn row_conflict_costs_more_than_hit() {
        let cfg = presets::tpuv6e_hardware().mem.dram;
        let mut m = DramModel::new(&cfg, 64, 1700.0);
        let (_, bank0, row0) = m.map(0);
        // find an address in the same bank but a different row
        let mut conflict_addr = None;
        for i in 1..100_000u64 {
            let a = i * 64;
            let (_, b, r) = m.map(a);
            if b == bank0 && r != row0 {
                conflict_addr = Some(a);
                break;
            }
        }
        let conflict_addr = conflict_addr.expect("found conflicting address");

        let hit_done = {
            let mut m = DramModel::new(&cfg, 64, 1700.0);
            m.access(0, 0);
            let t0 = 1000;
            m.access(0, t0) - t0
        };
        let conflict_done = {
            let mut m = DramModel::new(&cfg, 64, 1700.0);
            m.access(0, 0);
            let t0 = 1000;
            m.access(conflict_addr, t0) - t0
        };
        assert!(
            conflict_done > hit_done,
            "conflict {conflict_done} <= hit {hit_done}"
        );
    }

    #[test]
    fn channel_interleave_spreads_consecutive_lines() {
        let m = model();
        let (c0, _, _) = m.map(0);
        let (c1, _, _) = m.map(64);
        assert_ne!(c0, c1);
    }

    #[test]
    fn bus_serializes_same_channel() {
        let mut m = model();
        let stride = 16 * 64u64; // same channel, likely same row
        let d1 = m.access(0, 0);
        let d2 = m.access(stride * 100, 0); // same channel, other row/bank
        assert!(d2 > d1, "second access must queue behind the first burst");
    }

    #[test]
    fn different_channels_proceed_in_parallel() {
        let mut m = model();
        let d1 = m.access(0, 0);
        let d2 = m.access(64, 0); // next channel
        // both row misses starting at 0: identical latency, no queuing
        assert_eq!(d1, d2);
    }

    #[test]
    fn counts_accumulate() {
        let mut m = model();
        for i in 0..100u64 {
            m.access(i * 64, 0);
        }
        assert_eq!(m.reads(), 100);
        assert_eq!(m.row_hits() + m.row_misses() + m.row_conflicts(), 100);
    }
}

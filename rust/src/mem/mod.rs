//! Memory hierarchy: on-chip local buffer (SPM / cache / pinning),
//! replacement policies, software prefetch, the FR-FCFS memory
//! controller, and the DRAMSim3-lite off-chip model.
//!
//! The paper's central claim is that embedding performance is governed by
//! this hierarchy — everything in this module exists so the engine can
//! answer "which accesses stay on-chip, and what do the rest cost?"

pub mod controller;
pub mod dram;
pub mod onchip;
pub mod policy;
pub mod prefetch;

pub use controller::{Completion, MemController};
pub use dram::DramModel;
pub use onchip::{AccessOutcome, Cache};
pub use policy::{PinSet, PolicyImpl, ReplacePolicy};
pub use prefetch::SoftwarePrefetcher;

//! FIFO replacement: evict in fill order, ignoring re-reference.

use super::ReplacePolicy;

#[derive(Clone)]
pub struct Fifo {
    ways: usize,
    next: Vec<u32>, // per-set round-robin fill pointer
}

impl Fifo {
    pub fn new(sets: usize, ways: usize) -> Self {
        Fifo { ways, next: vec![0; sets] }
    }

    /// Copy `set`'s fill pointer from a speculative fork of this instance.
    pub fn adopt_set(&mut self, set: usize, from: &Fifo) {
        self.next[set] = from.next[set];
    }
}

impl ReplacePolicy for Fifo {
    #[inline]
    fn on_hit(&mut self, _set: usize, _way: usize) {}

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        // advance only when the fill used our predicted slot (keeps the
        // pointer honest under out-of-order fills from warmup)
        if self.next[set] as usize == way {
            self.next[set] = ((way + 1) % self.ways) as u32;
        }
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        self.next[set] as usize
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_eviction() {
        let mut p = Fifo::new(1, 3);
        for expect in [0, 1, 2, 0, 1] {
            let v = p.victim(0);
            assert_eq!(v, expect);
            p.on_fill(0, v);
        }
    }

    #[test]
    fn hits_do_not_change_order() {
        let mut p = Fifo::new(1, 2);
        p.on_fill(0, 0);
        p.on_hit(0, 0);
        assert_eq!(p.victim(0), 1);
    }
}

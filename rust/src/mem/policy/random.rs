//! Random replacement (deterministic PRNG — reproducible runs).

use super::ReplacePolicy;
use crate::testutil::SplitMix64;

#[derive(Clone)]
pub struct RandomRepl {
    ways: usize,
    rng: SplitMix64,
}

impl RandomRepl {
    pub fn new(_sets: usize, ways: usize) -> Self {
        RandomRepl { ways, rng: SplitMix64::new(0xBADC_0FFE) }
    }
}

impl ReplacePolicy for RandomRepl {
    #[inline]
    fn on_hit(&mut self, _set: usize, _way: usize) {}

    #[inline]
    fn on_fill(&mut self, _set: usize, _way: usize) {}

    #[inline]
    fn victim(&mut self, _set: usize) -> usize {
        self.rng.next_below(self.ways as u64) as usize
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victims_in_range_and_varied() {
        let mut p = RandomRepl::new(1, 8);
        let mut seen = [false; 8];
        for _ in 0..256 {
            let v = p.victim(0);
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 6);
    }
}

//! Least-recently-used replacement via per-way monotonic timestamps.

use super::ReplacePolicy;

/// Timestamp LRU: each (set, way) stores the global access counter at its
/// last touch; the victim is the way with the smallest stamp. O(ways)
/// victim search, O(1) hit/fill — the classic tag-store layout.
#[derive(Clone)]
pub struct Lru {
    ways: usize,
    stamps: Vec<u64>,
    clock: u64,
}

impl Lru {
    pub fn new(sets: usize, ways: usize) -> Self {
        Lru { ways, stamps: vec![0; sets * ways], clock: 0 }
    }

    #[inline]
    fn touch(&mut self, set: usize, way: usize) {
        self.clock += 1;
        self.stamps[set * self.ways + way] = self.clock;
    }

    /// Copy `set`'s stamp row from a speculative fork of this instance.
    /// The merged clock takes the max so future stamps stay above every
    /// adopted one — within-set stamp *order* (all that victim selection
    /// observes) is preserved even though absolute values differ from a
    /// serial execution.
    pub fn adopt_set(&mut self, set: usize, from: &Lru) {
        let base = set * self.ways;
        self.stamps[base..base + self.ways]
            .copy_from_slice(&from.stamps[base..base + self.ways]);
        self.clock = self.clock.max(from.clock);
    }
}

impl ReplacePolicy for Lru {
    #[inline]
    fn on_hit(&mut self, set: usize, way: usize) {
        self.touch(set, way);
    }

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        self.touch(set, way);
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.ways;
        let mut best = 0;
        let mut best_stamp = u64::MAX;
        for w in 0..self.ways {
            let s = self.stamps[base + w];
            if s < best_stamp {
                best_stamp = s;
                best = w;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "lru"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let mut p = Lru::new(1, 4);
        for w in 0..4 {
            p.on_fill(0, w);
        }
        p.on_hit(0, 0); // 0 is now most recent; 1 is least
        assert_eq!(p.victim(0), 1);
    }

    #[test]
    fn hit_refreshes_recency() {
        let mut p = Lru::new(1, 2);
        p.on_fill(0, 0);
        p.on_fill(0, 1);
        p.on_hit(0, 0);
        assert_eq!(p.victim(0), 1);
        p.on_hit(0, 1);
        assert_eq!(p.victim(0), 0);
    }

    #[test]
    fn sets_are_independent() {
        let mut p = Lru::new(2, 2);
        p.on_fill(0, 0);
        p.on_fill(0, 1);
        p.on_fill(1, 1);
        p.on_fill(1, 0);
        assert_eq!(p.victim(0), 0);
        assert_eq!(p.victim(1), 1);
    }
}

//! SRRIP — Static Re-Reference Interval Prediction (Jaleel et al.,
//! ISCA'10), the policy the paper cites as "similar to the last-level
//! cache mode of MTIA".
//!
//! 2-bit RRPV per way. Fills insert at RRPV = 2 ("long re-reference"),
//! hits promote to 0, and the victim is the first way with RRPV = 3
//! (aging all ways by +1 until one appears, lowest way index wins ties —
//! the canonical formulation, and the one `champsim::srrip` must agree
//! with exactly for Fig. 4a).

use super::ReplacePolicy;

const MAX_RRPV: u8 = 3; // 2-bit
const INSERT_RRPV: u8 = 2;

#[derive(Clone)]
pub struct Srrip {
    ways: usize,
    rrpv: Vec<u8>,
}

impl Srrip {
    pub fn new(sets: usize, ways: usize) -> Self {
        Srrip { ways, rrpv: vec![MAX_RRPV; sets * ways] }
    }

    /// Copy `set`'s RRPV row from a speculative fork of this instance
    /// (all SRRIP state is per-set, so this is a complete merge).
    pub fn adopt_set(&mut self, set: usize, from: &Srrip) {
        let base = set * self.ways;
        self.rrpv[base..base + self.ways]
            .copy_from_slice(&from.rrpv[base..base + self.ways]);
    }
}

impl ReplacePolicy for Srrip {
    #[inline]
    fn on_hit(&mut self, set: usize, way: usize) {
        self.rrpv[set * self.ways + way] = 0;
    }

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        self.rrpv[set * self.ways + way] = INSERT_RRPV;
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.ways;
        loop {
            for w in 0..self.ways {
                if self.rrpv[base + w] == MAX_RRPV {
                    return w;
                }
            }
            for w in 0..self.ways {
                self.rrpv[base + w] += 1;
            }
        }
    }

    fn name(&self) -> &'static str {
        "srrip"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_insert_at_two() {
        let mut p = Srrip::new(1, 2);
        p.on_fill(0, 0);
        assert_eq!(p.rrpv[0], INSERT_RRPV);
    }

    #[test]
    fn hit_promotes_to_zero() {
        let mut p = Srrip::new(1, 2);
        p.on_fill(0, 0);
        p.on_hit(0, 0);
        assert_eq!(p.rrpv[0], 0);
    }

    #[test]
    fn victim_prefers_max_rrpv_lowest_way() {
        let mut p = Srrip::new(1, 4);
        // all start at MAX (cold): way 0 wins the tie
        assert_eq!(p.victim(0), 0);
        p.on_fill(0, 0); // rrpv 2
        // ways 1..3 still at MAX → way 1 is the first
        assert_eq!(p.victim(0), 1);
    }

    #[test]
    fn aging_when_no_max_present() {
        let mut p = Srrip::new(1, 2);
        p.on_fill(0, 0); // 2
        p.on_fill(0, 1); // 2
        p.on_hit(0, 1); // 0
        // no way at 3: age all (+1) -> way0=3, way1=1 -> victim 0
        assert_eq!(p.victim(0), 0);
        // aging persisted
        assert_eq!(p.rrpv[1], 1);
    }

    #[test]
    fn scan_resistance() {
        // A hot way re-referenced between scan bursts survives: with the
        // hot way at RRPV 0 and scans inserting at 2, the victim is
        // always a scan way. (Without re-references even a hot line ages
        // out — that is correct SRRIP behaviour.)
        let mut p = Srrip::new(1, 2);
        p.on_fill(0, 0); // hot line
        for _ in 0..8 {
            p.on_hit(0, 0); // keep hot at RRPV 0
            let v = p.victim(0);
            assert_eq!(v, 1, "scan must evict the scan way, not the hot way");
            p.on_fill(0, v);
        }
    }
}

//! BRRIP and DRRIP — the rest of the RRIP family (Jaleel et al.,
//! ISCA'10). The paper's config surface says "cache-based replacement
//! policies (e.g., LRU, SRRIP)"; BRRIP/DRRIP are the canonical next
//! steps and exercise the simulator's policy modularity.
//!
//! * **BRRIP** (Bimodal RRIP): inserts at distant RRPV (3) most of the
//!   time and at long (2) with low probability — thrash-resistant for
//!   cyclic working sets. The "probability" here is a deterministic
//!   1-in-32 counter so simulations stay reproducible.
//! * **DRRIP**: set-dueling between SRRIP and BRRIP. A few leader sets
//!   run each policy unconditionally; a saturating counter (PSEL) tracks
//!   which leader misses less, and follower sets adopt the winner.

use super::ReplacePolicy;

const MAX_RRPV: u8 = 3;
const LONG_RRPV: u8 = 2;
/// BRRIP inserts at LONG once per this many fills (deterministic).
const BRRIP_EPSILON: u32 = 32;
/// Leader sets per policy: every set with `set % 64 == 0` leads SRRIP,
/// `set % 64 == 1` leads BRRIP (constituency-based dueling).
const DUEL_MOD: usize = 64;
/// 10-bit saturating PSEL, initialized mid-range.
const PSEL_MAX: i32 = 1023;
const PSEL_INIT: i32 = 512;

/// Shared RRPV store + victim/aging logic (same as SRRIP's).
#[derive(Clone)]
struct Rrpv {
    ways: usize,
    rrpv: Vec<u8>,
}

impl Rrpv {
    fn new(sets: usize, ways: usize) -> Self {
        Rrpv { ways, rrpv: vec![MAX_RRPV; sets * ways] }
    }

    #[inline]
    fn set(&mut self, set: usize, way: usize, v: u8) {
        self.rrpv[set * self.ways + way] = v;
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.ways;
        loop {
            for w in 0..self.ways {
                if self.rrpv[base + w] == MAX_RRPV {
                    return w;
                }
            }
            for w in 0..self.ways {
                self.rrpv[base + w] += 1;
            }
        }
    }
}

/// Bimodal RRIP.
#[derive(Clone)]
pub struct Brrip {
    rrpv: Rrpv,
    fill_count: u32,
}

impl Brrip {
    pub fn new(sets: usize, ways: usize) -> Self {
        Brrip { rrpv: Rrpv::new(sets, ways), fill_count: 0 }
    }

    /// Bimodal insertion value (deterministic 1/32 long insertions).
    #[inline]
    fn insert_rrpv(fill_count: &mut u32) -> u8 {
        *fill_count = (*fill_count + 1) % BRRIP_EPSILON;
        if *fill_count == 0 {
            LONG_RRPV
        } else {
            MAX_RRPV
        }
    }
}

impl ReplacePolicy for Brrip {
    #[inline]
    fn on_hit(&mut self, set: usize, way: usize) {
        self.rrpv.set(set, way, 0);
    }

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        let v = Self::insert_rrpv(&mut self.fill_count);
        self.rrpv.set(set, way, v);
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        self.rrpv.victim(set)
    }

    fn name(&self) -> &'static str {
        "brrip"
    }
}

/// Dynamic RRIP with constituency set-dueling.
#[derive(Clone)]
pub struct Drrip {
    rrpv: Rrpv,
    brrip_fill_count: u32,
    /// Saturating policy selector: high -> SRRIP misses more -> use BRRIP.
    psel: i32,
}

#[derive(PartialEq)]
enum SetRole {
    SrripLeader,
    BrripLeader,
    Follower,
}

impl Drrip {
    pub fn new(sets: usize, ways: usize) -> Self {
        Drrip { rrpv: Rrpv::new(sets, ways), brrip_fill_count: 0, psel: PSEL_INIT }
    }

    fn role(set: usize) -> SetRole {
        match set % DUEL_MOD {
            0 => SetRole::SrripLeader,
            1 => SetRole::BrripLeader,
            _ => SetRole::Follower,
        }
    }

    /// Followers use BRRIP when SRRIP's leaders miss more (psel high).
    fn follower_uses_brrip(&self) -> bool {
        self.psel > PSEL_INIT
    }
}

impl ReplacePolicy for Drrip {
    #[inline]
    fn on_hit(&mut self, set: usize, way: usize) {
        self.rrpv.set(set, way, 0);
    }

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        // a fill IS a miss: leaders vote via PSEL
        let use_brrip = match Self::role(set) {
            SetRole::SrripLeader => {
                self.psel = (self.psel + 1).min(PSEL_MAX);
                false
            }
            SetRole::BrripLeader => {
                // eonsim-lint: allow(underflow, reason = "psel is a signed i32 saturated into [0, PSEL_MAX] by the max(0); no unsigned wrap is possible")
                self.psel = (self.psel - 1).max(0);
                true
            }
            SetRole::Follower => self.follower_uses_brrip(),
        };
        let v = if use_brrip {
            Brrip::insert_rrpv(&mut self.brrip_fill_count)
        } else {
            LONG_RRPV
        };
        self.rrpv.set(set, way, v);
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        self.rrpv.victim(set)
    }

    fn name(&self) -> &'static str {
        "drrip"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CachePolicyKind;
    use crate::mem::Cache;
    use crate::testutil::SplitMix64;
    use crate::trace::ZipfSampler;

    #[test]
    fn brrip_inserts_mostly_distant() {
        let mut p = Brrip::new(1, 4);
        let mut distant = 0;
        for i in 0..BRRIP_EPSILON as usize {
            p.on_fill(0, i % 4);
            if p.rrpv.rrpv[i % 4] == MAX_RRPV {
                distant += 1;
            }
        }
        assert_eq!(distant, BRRIP_EPSILON as usize - 1, "exactly one long insertion");
    }

    #[test]
    fn brrip_hit_promotes() {
        let mut p = Brrip::new(1, 2);
        p.on_fill(0, 0);
        p.on_hit(0, 0);
        assert_eq!(p.rrpv.rrpv[0], 0);
    }

    #[test]
    fn drrip_psel_moves_with_leader_misses() {
        let mut p = Drrip::new(DUEL_MOD * 2, 4);
        let start = p.psel;
        p.on_fill(0, 0); // SRRIP leader miss
        assert_eq!(p.psel, start + 1);
        p.on_fill(1, 0); // BRRIP leader miss
        p.on_fill(1, 1);
        assert_eq!(p.psel, start - 1);
    }

    #[test]
    fn drrip_followers_adopt_winner() {
        let mut p = Drrip::new(DUEL_MOD * 2, 4);
        // hammer the SRRIP leader with misses -> psel rises -> followers BRRIP
        for i in 0..100 {
            p.on_fill(0, i % 4);
        }
        assert!(p.follower_uses_brrip());
        // follower fill should now use bimodal (mostly MAX) insertion
        let mut distant = 0;
        for i in 0..16 {
            p.on_fill(2, i % 4);
            if p.rrpv.rrpv[2 * 4 + i % 4] == MAX_RRPV {
                distant += 1;
            }
        }
        assert!(distant >= 14, "follower should insert distant, got {distant}");
    }

    #[test]
    fn brrip_resists_thrash_where_srrip_does_not() {
        // cyclic working set 3x a 2-way set: SRRIP thrashes (insert-at-2
        // ages out), BRRIP's distant insertion keeps a subset resident.
        let stride = 4 * 64u64;
        let addrs: Vec<u64> = (0..3u64).map(|i| i * stride).collect();
        let run = |kind| {
            let mut c = Cache::new(512, 64, 2, kind);
            for _ in 0..300 {
                for &a in &addrs {
                    c.access(a);
                }
            }
            c.hits()
        };
        let srrip = run(CachePolicyKind::Srrip);
        let brrip = run(CachePolicyKind::Brrip);
        assert_eq!(srrip, 0, "SRRIP thrashes the cyclic set");
        assert!(brrip > 100, "BRRIP retains lines, got {brrip}");
    }

    #[test]
    fn drrip_tracks_better_policy_on_mixed_traffic() {
        // skewed reuse traffic: all three RRIP variants complete and
        // DRRIP lands within the SRRIP/BRRIP envelope (±15 % slack for
        // dueling overhead on leaders).
        let z = ZipfSampler::new(1 << 14, 1.1);
        let run = |kind| {
            let mut c = Cache::new(64 << 10, 64, 16, kind);
            let mut rng = SplitMix64::new(11);
            for _ in 0..200_000 {
                c.access(z.sample(&mut rng) * 64);
            }
            c.hits()
        };
        let srrip = run(CachePolicyKind::Srrip);
        let brrip = run(CachePolicyKind::Brrip);
        let drrip = run(CachePolicyKind::Drrip);
        let lo = srrip.min(brrip);
        let hi = srrip.max(brrip);
        assert!(
            drrip as f64 >= lo as f64 * 0.85 && drrip as f64 <= hi as f64 * 1.15,
            "drrip {drrip} outside [{lo}, {hi}] envelope"
        );
    }
}

//! Modular replacement policies for cache-mode on-chip memory
//! (paper §III: "modularized on-chip memory management policies").
//!
//! Each policy owns its per-way metadata and answers three questions:
//! what happens on a hit, what happens on a fill, and which way to evict.
//! [`PolicyImpl`] gives static dispatch over the configured policy so the
//! per-access hot path stays branch-predictable and allocation-free.

pub mod fifo;
pub mod lru;
pub mod pinning;
pub mod random;
pub mod rrip;
pub mod srrip;

pub use fifo::Fifo;
pub use lru::Lru;
pub use pinning::PinSet;
pub use random::RandomRepl;
pub use rrip::{Brrip, Drrip};
pub use srrip::Srrip;

use crate::config::CachePolicyKind;

/// Replacement-policy interface over a `sets x ways` tag geometry.
pub trait ReplacePolicy {
    /// A line in `(set, way)` was re-referenced.
    fn on_hit(&mut self, set: usize, way: usize);
    /// A new line was installed into `(set, way)`.
    fn on_fill(&mut self, set: usize, way: usize);
    /// Choose the victim way in `set`. Called only when all ways are valid.
    fn victim(&mut self, set: usize) -> usize;
    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// Statically dispatched policy selection.
#[derive(Clone)]
pub enum PolicyImpl {
    Lru(Lru),
    Srrip(Srrip),
    Brrip(Brrip),
    Drrip(Drrip),
    Fifo(Fifo),
    Random(RandomRepl),
}

impl PolicyImpl {
    pub fn new(kind: CachePolicyKind, sets: usize, ways: usize) -> Self {
        match kind {
            CachePolicyKind::Lru => PolicyImpl::Lru(Lru::new(sets, ways)),
            CachePolicyKind::Srrip => PolicyImpl::Srrip(Srrip::new(sets, ways)),
            CachePolicyKind::Brrip => PolicyImpl::Brrip(Brrip::new(sets, ways)),
            CachePolicyKind::Drrip => PolicyImpl::Drrip(Drrip::new(sets, ways)),
            CachePolicyKind::Fifo => PolicyImpl::Fifo(Fifo::new(sets, ways)),
            CachePolicyKind::Random => PolicyImpl::Random(RandomRepl::new(sets, ways)),
        }
    }

    /// Whether this policy's replacement state is confined per set, so a
    /// speculative fork touching disjoint sets can be merged back
    /// set-by-set without observable divergence. BRRIP (global
    /// `fill_count`), DRRIP (global `psel` duel) and Random (one shared
    /// RNG stream) have cross-set state and must decline.
    pub fn per_set_safe(&self) -> bool {
        matches!(
            self,
            PolicyImpl::Lru(_) | PolicyImpl::Srrip(_) | PolicyImpl::Fifo(_)
        )
    }

    /// Copy `set`'s replacement metadata from a speculative fork. Only
    /// valid for [`per_set_safe`](Self::per_set_safe) policies on forks
    /// cloned from this instance (identical geometry and variant).
    pub fn adopt_set(&mut self, set: usize, from: &PolicyImpl) {
        match (self, from) {
            (PolicyImpl::Lru(a), PolicyImpl::Lru(b)) => a.adopt_set(set, b),
            (PolicyImpl::Srrip(a), PolicyImpl::Srrip(b)) => a.adopt_set(set, b),
            (PolicyImpl::Fifo(a), PolicyImpl::Fifo(b)) => a.adopt_set(set, b),
            _ => unreachable!("adopt_set is gated on per_set_safe policies"),
        }
    }
}

impl ReplacePolicy for PolicyImpl {
    #[inline]
    fn on_hit(&mut self, set: usize, way: usize) {
        match self {
            PolicyImpl::Lru(p) => p.on_hit(set, way),
            PolicyImpl::Srrip(p) => p.on_hit(set, way),
            PolicyImpl::Brrip(p) => p.on_hit(set, way),
            PolicyImpl::Drrip(p) => p.on_hit(set, way),
            PolicyImpl::Fifo(p) => p.on_hit(set, way),
            PolicyImpl::Random(p) => p.on_hit(set, way),
        }
    }

    #[inline]
    fn on_fill(&mut self, set: usize, way: usize) {
        match self {
            PolicyImpl::Lru(p) => p.on_fill(set, way),
            PolicyImpl::Srrip(p) => p.on_fill(set, way),
            PolicyImpl::Brrip(p) => p.on_fill(set, way),
            PolicyImpl::Drrip(p) => p.on_fill(set, way),
            PolicyImpl::Fifo(p) => p.on_fill(set, way),
            PolicyImpl::Random(p) => p.on_fill(set, way),
        }
    }

    #[inline]
    fn victim(&mut self, set: usize) -> usize {
        match self {
            PolicyImpl::Lru(p) => p.victim(set),
            PolicyImpl::Srrip(p) => p.victim(set),
            PolicyImpl::Brrip(p) => p.victim(set),
            PolicyImpl::Drrip(p) => p.victim(set),
            PolicyImpl::Fifo(p) => p.victim(set),
            PolicyImpl::Random(p) => p.victim(set),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            PolicyImpl::Lru(p) => p.name(),
            PolicyImpl::Srrip(p) => p.name(),
            PolicyImpl::Brrip(p) => p.name(),
            PolicyImpl::Drrip(p) => p.name(),
            PolicyImpl::Fifo(p) => p.name(),
            PolicyImpl::Random(p) => p.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_impl_dispatch_names() {
        for (kind, name) in [
            (CachePolicyKind::Lru, "lru"),
            (CachePolicyKind::Srrip, "srrip"),
            (CachePolicyKind::Brrip, "brrip"),
            (CachePolicyKind::Drrip, "drrip"),
            (CachePolicyKind::Fifo, "fifo"),
            (CachePolicyKind::Random, "random"),
        ] {
            assert_eq!(PolicyImpl::new(kind, 4, 4).name(), name);
        }
    }
}

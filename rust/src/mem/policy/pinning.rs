//! Profiling-based pinning (paper §IV, "Profiling"): track per-vector
//! access frequency, pin the hottest vectors into on-chip memory up to
//! capacity, and serve everything else from off-chip as the SPM path
//! does. Mitigates thrashing under low-skew traffic where LRU/SRRIP
//! degrade.

use std::collections::{BTreeMap, BTreeSet};

/// Frequency profile over `(table, row)` vector ids. Ordered maps keep
/// every derived artifact (top-K sets, pinned bytes) independent of
/// insertion/hash order, so reports stay byte-identical across runs.
#[derive(Debug, Default, Clone)]
pub struct Profile {
    counts: BTreeMap<(u32, u64), u64>,
}

impl Profile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Profile already-generated batch traces — the offline pass shared
    /// by profiling-based pinning and hot-row replication. Feed this the
    /// engine's shared [`crate::trace::WorkloadTrace`] so the trace is
    /// generated once, not once per consumer.
    pub fn from_batches<'a>(
        batches: impl IntoIterator<Item = &'a crate::trace::BatchTrace>,
    ) -> Profile {
        let mut profile = Profile::new();
        for b in batches {
            for l in &b.lookups {
                profile.record(l.table, l.row);
            }
        }
        profile
    }

    /// Profile the workload's full index trace, generating it in the
    /// process. Standalone consumers only — inside a simulation run,
    /// share the engine's [`crate::trace::WorkloadTrace`] via
    /// [`from_batches`](Self::from_batches) instead of regenerating.
    pub fn from_workload(
        workload: &crate::config::WorkloadConfig,
    ) -> anyhow::Result<Profile> {
        let trace = crate::trace::WorkloadTrace::generate(workload)?;
        Ok(Profile::from_batches(trace.batches()))
    }

    /// Record one lookup of `(table, row)`.
    #[inline]
    pub fn record(&mut self, table: u32, row: u64) {
        *self.counts.entry((table, row)).or_insert(0) += 1;
    }

    /// Copy of this profile without the rows `excluded` matches. Used
    /// when hot-row replication already pins the top-K rows on-chip:
    /// the pinning policy's budget then goes to the *next* hottest rows
    /// instead of duplicating the replicas.
    pub fn without<F: Fn(u32, u64) -> bool>(&self, excluded: F) -> Profile {
        Profile {
            counts: self
                .counts
                .iter()
                .filter(|((t, r), _)| !excluded(*t, *r))
                .map(|(k, v)| (*k, *v))
                .collect(),
        }
    }

    pub fn unique_vectors(&self) -> usize {
        self.counts.len()
    }

    /// The `k` hottest vectors, ties broken deterministically by id.
    pub fn top_k(&self, k: usize) -> Vec<(u32, u64)> {
        let mut entries: Vec<(&(u32, u64), &u64)> = self.counts.iter().collect();
        entries.sort_unstable_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
        entries.into_iter().take(k).map(|(id, _)| *id).collect()
    }
}

/// The pinned-vector set derived from a [`Profile`] and a capacity.
#[derive(Debug, Clone)]
pub struct PinSet {
    pinned: BTreeSet<(u32, u64)>,
    capacity_vectors: usize,
}

impl PinSet {
    /// Pin the hottest vectors that fit: `capacity_bytes / vec_bytes`
    /// slots (the paper pins whole vectors, not lines).
    pub fn from_profile(profile: &Profile, capacity_bytes: u64, vec_bytes: u64) -> Self {
        let capacity_vectors = (capacity_bytes / vec_bytes.max(1)) as usize;
        let pinned = profile
            .top_k(capacity_vectors)
            .into_iter()
            .collect::<BTreeSet<_>>();
        PinSet { pinned, capacity_vectors }
    }

    /// Empty pin set (profiling disabled).
    pub fn empty() -> Self {
        PinSet { pinned: BTreeSet::new(), capacity_vectors: 0 }
    }

    #[inline]
    pub fn is_pinned(&self, table: u32, row: u64) -> bool {
        self.pinned.contains(&(table, row))
    }

    pub fn len(&self) -> usize {
        self.pinned.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pinned.is_empty()
    }

    pub fn capacity_vectors(&self) -> usize {
        self.capacity_vectors
    }

    /// Sorted (ascending `(table, row)`) iterator over the pinned ids —
    /// merge-join input for [`crate::trace::BatchPlan`].
    pub fn iter(&self) -> impl Iterator<Item = &(u32, u64)> {
        self.pinned.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile_with(counts: &[((u32, u64), u64)]) -> Profile {
        let mut p = Profile::new();
        for &((t, r), c) in counts {
            for _ in 0..c {
                p.record(t, r);
            }
        }
        p
    }

    #[test]
    fn top_k_orders_by_frequency() {
        let p = profile_with(&[((0, 1), 5), ((0, 2), 10), ((1, 3), 1)]);
        assert_eq!(p.top_k(2), vec![(0, 2), (0, 1)]);
    }

    #[test]
    fn top_k_ties_deterministic() {
        let p = profile_with(&[((0, 5), 3), ((0, 1), 3), ((0, 9), 3)]);
        assert_eq!(p.top_k(2), vec![(0, 1), (0, 5)]);
    }

    #[test]
    fn pinset_respects_capacity() {
        let p = profile_with(&[((0, 1), 5), ((0, 2), 4), ((0, 3), 3)]);
        // room for exactly 2 vectors of 512 B
        let pins = PinSet::from_profile(&p, 1024, 512);
        assert_eq!(pins.len(), 2);
        assert!(pins.is_pinned(0, 1));
        assert!(pins.is_pinned(0, 2));
        assert!(!pins.is_pinned(0, 3));
    }

    #[test]
    fn pinset_smaller_than_capacity_when_few_vectors() {
        let p = profile_with(&[((0, 1), 1)]);
        let pins = PinSet::from_profile(&p, 1 << 20, 512);
        assert_eq!(pins.len(), 1);
        assert!(pins.capacity_vectors() > 1);
    }

    #[test]
    fn empty_pinset() {
        let pins = PinSet::empty();
        assert!(pins.is_empty());
        assert!(!pins.is_pinned(0, 0));
    }

    #[test]
    fn without_excludes_rows_and_promotes_next_hottest() {
        let p = profile_with(&[((0, 1), 5), ((0, 2), 4), ((0, 3), 3)]);
        let filtered = p.without(|t, r| (t, r) == (0, 1));
        assert_eq!(filtered.unique_vectors(), 2);
        // the pin budget now goes to the next-hottest rows
        assert_eq!(filtered.top_k(1), vec![(0, 2)]);
        // a no-op filter leaves the ordering untouched
        let same = p.without(|_, _| false);
        assert_eq!(same.top_k(3), p.top_k(3));
    }

    #[test]
    fn from_workload_is_deterministic() {
        let mut w = crate::config::presets::dlrm_rmc2_small(4);
        w.embedding.num_tables = 2;
        w.embedding.rows_per_table = 1000;
        w.embedding.pool = 4;
        w.num_batches = 2;
        let a = Profile::from_workload(&w).unwrap();
        let b = Profile::from_workload(&w).unwrap();
        assert_eq!(a.unique_vectors(), b.unique_vectors());
        assert_eq!(a.top_k(10), b.top_k(10));
        // 2 batches x 4 samples x 2 tables x 4 pool lookups recorded
        assert!(a.unique_vectors() > 0);
    }
}

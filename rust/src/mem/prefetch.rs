//! Software prefetcher model (paper §I/§II: "software prefetching" is one
//! of the on-chip management schemes NPUs employ).
//!
//! Embedding lookups expose their *entire* address list ahead of time —
//! the index vector arrives before any gather starts — so an NPU runtime
//! can software-prefetch `depth` vectors ahead of the consuming kernel.
//! In the timing engine this converts off-chip latency into bandwidth
//! occupancy as long as the prefetch queue stays ahead; the model below
//! tracks how far ahead the stream is and reports, per access, whether
//! its latency is covered.

/// Prefetch stream state for one embedding kernel invocation.
#[derive(Debug, Clone)]
pub struct SoftwarePrefetcher {
    /// How many vectors ahead the runtime issues prefetches.
    depth: usize,
    /// Lines prefetched but not yet consumed.
    inflight: usize,
    issued: u64,
    covered: u64,
    uncovered: u64,
}

impl SoftwarePrefetcher {
    pub fn new(depth: usize) -> Self {
        SoftwarePrefetcher { depth, inflight: 0, issued: 0, covered: 0, uncovered: 0 }
    }

    /// Disabled prefetcher (depth 0): nothing is ever covered.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The runtime issues prefetches for upcoming lines (bounded by depth).
    #[inline]
    pub fn issue(&mut self, lines: usize) {
        if self.depth == 0 {
            return;
        }
        let room = self.depth.saturating_sub(self.inflight);
        let take = lines.min(room);
        self.inflight += take;
        self.issued += take as u64;
    }

    /// The kernel consumes one line; returns true if the prefetcher had
    /// it in flight (latency covered, only bandwidth is paid).
    #[inline]
    pub fn consume(&mut self) -> bool {
        if self.inflight > 0 {
            // eonsim-lint: allow(underflow, reason = "guarded by the inflight > 0 branch condition directly above")
            self.inflight -= 1;
            self.covered += 1;
            true
        } else {
            self.uncovered += 1;
            false
        }
    }

    pub fn covered(&self) -> u64 {
        self.covered
    }

    pub fn uncovered(&self) -> u64 {
        self.uncovered
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Fraction of consumed lines whose latency was hidden.
    pub fn coverage(&self) -> f64 {
        let total = self.covered + self.uncovered;
        if total == 0 {
            0.0
        } else {
            self.covered as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_covers_nothing() {
        let mut p = SoftwarePrefetcher::disabled();
        p.issue(100);
        assert!(!p.consume());
        assert_eq!(p.coverage(), 0.0);
    }

    #[test]
    fn deep_prefetch_covers_stream() {
        let mut p = SoftwarePrefetcher::new(8);
        for _ in 0..100 {
            p.issue(1);
            assert!(p.consume());
        }
        assert_eq!(p.coverage(), 1.0);
    }

    #[test]
    fn inflight_bounded_by_depth() {
        let mut p = SoftwarePrefetcher::new(4);
        p.issue(100);
        assert_eq!(p.issued(), 4);
        for _ in 0..4 {
            assert!(p.consume());
        }
        assert!(!p.consume(), "fifth consume uncovered");
    }

    #[test]
    fn coverage_partial() {
        let mut p = SoftwarePrefetcher::new(1);
        p.issue(1);
        p.consume(); // covered
        p.consume(); // uncovered
        assert!((p.coverage() - 0.5).abs() < 1e-9);
    }
}

//! On-chip local-buffer model: a set-associative cache with pluggable
//! replacement (cache mode), plus the SPM and pinning access paths the
//! engine composes around it.
//!
//! The cache operates at access-granularity lines. Geometry is derived
//! from capacity / line size / associativity; tags are stored in a flat
//! `sets x ways` array with `u64::MAX` as the invalid sentinel, and the
//! replacement policy keeps its own parallel metadata (see
//! [`crate::mem::policy`]).

use crate::config::CachePolicyKind;
use crate::mem::policy::{PolicyImpl, ReplacePolicy};

/// Result of one cache access at line granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessOutcome {
    Hit,
    /// Miss; `evicted` is the replaced line address, if any.
    Miss { evicted: Option<u64> },
}

impl AccessOutcome {
    #[inline]
    pub fn is_hit(self) -> bool {
        matches!(self, AccessOutcome::Hit)
    }
}

/// Set-associative cache over line addresses.
#[derive(Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_bytes: u64,
    tags: Vec<u64>,
    policy: PolicyImpl,
    hits: u64,
    misses: u64,
}

const INVALID: u64 = u64::MAX;

impl Cache {
    /// `capacity_bytes` / `line_bytes` / `assoc` must produce >= 1 set;
    /// sets are rounded down to a power of two for cheap indexing, and
    /// ways are clamped to the line count so `sets * ways * line_bytes`
    /// never exceeds the configured capacity (a tiny capacity with a
    /// large associativity degenerates to fewer ways, not more storage).
    pub fn new(
        capacity_bytes: u64,
        line_bytes: u64,
        assoc: usize,
        kind: CachePolicyKind,
    ) -> Self {
        assert!(line_bytes.is_power_of_two());
        let lines = (capacity_bytes / line_bytes).max(1) as usize;
        let ways = assoc.clamp(1, lines);
        let sets_raw = (lines / ways).max(1);
        let sets = if sets_raw.is_power_of_two() {
            sets_raw
        } else {
            sets_raw.next_power_of_two() / 2
        };
        Cache {
            sets,
            ways,
            line_bytes,
            tags: vec![INVALID; sets * ways],
            policy: PolicyImpl::new(kind, sets, ways),
            hits: 0,
            misses: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Access one line address: lookup, and on miss install (filling an
    /// invalid way if present, else evicting the policy's victim).
    pub fn access(&mut self, line_addr: u64) -> AccessOutcome {
        let line = line_addr / self.line_bytes;
        // eonsim-lint: allow(underflow, reason = "sets is (lines/ways).max(1) rounded to a power of two at construction, so sets >= 1 and the mask cannot wrap")
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.ways;

        for w in 0..self.ways {
            if self.tags[base + w] == line {
                self.hits += 1;
                self.policy.on_hit(set, w);
                return AccessOutcome::Hit;
            }
        }
        self.misses += 1;

        // prefer an invalid way
        for w in 0..self.ways {
            if self.tags[base + w] == INVALID {
                self.tags[base + w] = line;
                self.policy.on_fill(set, w);
                return AccessOutcome::Miss { evicted: None };
            }
        }
        let victim = self.policy.victim(set);
        debug_assert!(victim < self.ways);
        let evicted = self.tags[base + victim] * self.line_bytes;
        self.tags[base + victim] = line;
        self.policy.on_fill(set, victim);
        AccessOutcome::Miss { evicted: Some(evicted) }
    }

    /// Lookup without state change (for invariant checks in tests).
    pub fn probe(&self, line_addr: u64) -> bool {
        let line = line_addr / self.line_bytes;
        // eonsim-lint: allow(underflow, reason = "sets >= 1 by construction (same invariant as access)")
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.ways;
        (0..self.ways).any(|w| self.tags[base + w] == line)
    }

    /// Number of valid lines currently resident.
    pub fn occupancy(&self) -> usize {
        self.tags.iter().filter(|&&t| t != INVALID).count()
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Set index a line address maps to — the conservative-footprint key
    /// used by speculative cross-batch execution (`[sim] speculate_batches`).
    #[inline]
    pub fn set_of(&self, line_addr: u64) -> usize {
        let line = line_addr / self.line_bytes;
        // eonsim-lint: allow(underflow, reason = "sets >= 1 by construction (same invariant as access)")
        (line as usize) & (self.sets - 1)
    }

    /// Whether the replacement policy tolerates set-granular merging of a
    /// speculative fork (see [`PolicyImpl::per_set_safe`]).
    pub fn per_set_safe(&self) -> bool {
        self.policy.per_set_safe()
    }

    /// Adopt `set`'s tag row and replacement metadata from a speculative
    /// fork cloned from this instance. Sound only when no other execution
    /// touched `set` since the fork (disjoint-footprint commit rule).
    pub fn adopt_set(&mut self, set: usize, from: &Cache) {
        debug_assert_eq!(self.sets, from.sets);
        debug_assert_eq!(self.ways, from.ways);
        let base = set * self.ways;
        self.tags[base..base + self.ways]
            .copy_from_slice(&from.tags[base..base + self.ways]);
        self.policy.adopt_set(set, &from.policy);
    }

    /// Fold a committed fork's hit/miss deltas (relative to the `base`
    /// stats captured at fork time) into this instance's counters.
    pub fn absorb_stats(&mut self, fork_hits: u64, fork_misses: u64, base_hits: u64, base_misses: u64) {
        self.hits += fork_hits.saturating_sub(base_hits);
        self.misses += fork_misses.saturating_sub(base_misses);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, SplitMix64};

    fn small(kind: CachePolicyKind) -> Cache {
        // 4 sets x 2 ways x 64 B lines = 512 B
        Cache::new(512, 64, 2, kind)
    }

    #[test]
    fn geometry() {
        let c = small(CachePolicyKind::Lru);
        assert_eq!(c.sets(), 4);
        assert_eq!(c.ways(), 2);
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = small(CachePolicyKind::Lru);
        assert!(!c.access(0).is_hit());
        assert!(c.access(0).is_hit());
        assert!(c.access(63).is_hit(), "same line");
        assert!(!c.access(64).is_hit(), "next line");
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 2);
    }

    #[test]
    fn eviction_reports_victim_address() {
        let mut c = small(CachePolicyKind::Lru);
        // set 0 holds lines 0, 4*64=256... set index = line % 4
        c.access(0); // line 0 -> set 0
        c.access(256); // line 4 -> set 0
        let out = c.access(512); // line 8 -> set 0, evicts line 0 (LRU)
        match out {
            AccessOutcome::Miss { evicted: Some(addr) } => assert_eq!(addr, 0),
            other => panic!("expected eviction, got {other:?}"),
        }
        assert!(!c.probe(0));
        assert!(c.probe(256));
    }

    #[test]
    fn occupancy_bounded_by_capacity() {
        // randomized over capacity and associativity, including tiny
        // capacities with assoc > capacity/line (regression: ways used
        // to stay at `assoc`, letting occupancy exceed capacity)
        forall("occupancy bound", 16, |rng: &mut SplitMix64| {
            let capacity = 64u64 << rng.next_below(6); // 64 B .. 2 KiB
            let assoc = 1usize << rng.next_below(6); // 1 .. 32 ways
            let kind = [CachePolicyKind::Srrip, CachePolicyKind::Lru]
                [rng.next_below(2) as usize];
            let mut c = Cache::new(capacity, 64, assoc, kind);
            for _ in 0..2000 {
                c.access(rng.next_below(1 << 20) & !63);
            }
            assert!(
                c.occupancy() as u64 * 64 <= capacity,
                "occupancy {} lines exceeds capacity {capacity} B \
                 (assoc {assoc}, sets {}, ways {})",
                c.occupancy(),
                c.sets(),
                c.ways()
            );
        });
    }

    #[test]
    fn oversized_assoc_clamps_to_line_count() {
        // 128 B / 64 B lines = 2 lines, requested 16-way: geometry must
        // clamp so modeled storage fits the capacity
        let mut c = Cache::new(128, 64, 16, CachePolicyKind::Lru);
        assert!(c.sets() * c.ways() <= 2, "{}x{}", c.sets(), c.ways());
        for i in 0..64u64 {
            c.access(i * 64);
        }
        assert!(c.occupancy() <= 2);
    }

    #[test]
    fn hits_plus_misses_equals_accesses() {
        forall("h+m == n", 8, |rng: &mut SplitMix64| {
            let mut c = Cache::new(2048, 64, 4, CachePolicyKind::Lru);
            let n = 5000;
            for _ in 0..n {
                c.access(rng.next_below(1 << 16));
            }
            assert_eq!(c.hits() + c.misses(), n);
        });
    }

    #[test]
    fn working_set_within_capacity_never_misses_after_warmup() {
        // fully-associative-equivalent check per set: touch 8 lines that
        // all fit, loop them; after warmup every access hits (LRU).
        let mut c = Cache::new(512, 64, 2, CachePolicyKind::Lru);
        let lines: Vec<u64> = (0..8u64).map(|i| i * 64).collect();
        for &a in &lines {
            c.access(a);
        }
        c.reset_stats();
        for _ in 0..10 {
            for &a in &lines {
                assert!(c.access(a).is_hit());
            }
        }
    }

    #[test]
    fn lru_thrashes_cyclic_working_set() {
        // cyclic working set one larger than a set's ways: LRU misses
        // every access after the cold fills.
        let line = 64u64;
        let stride = 4 * line; // same set every time (4 sets)
        let addrs: Vec<u64> = (0..3u64).map(|i| i * stride).collect(); // 3 > 2 ways
        let mut c = small(CachePolicyKind::Lru);
        for _ in 0..200 {
            for &a in &addrs {
                c.access(a);
            }
        }
        assert_eq!(c.hits(), 0, "LRU must thrash a cyclic overflow set");
    }

    #[test]
    fn srrip_retains_hot_line_under_scan_where_lru_thrashes() {
        // Mixed traffic: one hot line re-referenced every round + a
        // 2-line streaming scan into the same set. With 2 ways, LRU
        // evicts the hot line each round; SRRIP keeps it at RRPV 0 and
        // sacrifices scan lines instead (the Fig. 4b mechanism).
        let line = 64u64;
        let stride = 4 * line;
        let hot = 0u64;
        let run = |kind| {
            let mut c = small(kind);
            c.access(hot); // cold fill
            c.access(hot); // first re-reference marks it hot (RRPV 0)
            let mut scan = 1u64;
            let mut hot_hits = 0u64;
            for _ in 0..100 {
                if c.access(hot).is_hit() {
                    hot_hits += 1;
                }
                for _ in 0..2 {
                    c.access(scan * stride);
                    scan += 1;
                }
            }
            hot_hits
        };
        let lru = run(CachePolicyKind::Lru);
        let srrip = run(CachePolicyKind::Srrip);
        assert!(lru <= 1, "LRU must lose the hot line to the scan, got {lru}");
        assert!(srrip > 90, "SRRIP should retain the hot line, got {srrip}");
    }

    #[test]
    fn non_pow2_set_count_rounds_down() {
        // 3 ways, 960 B capacity -> 5 sets raw -> rounds to 4
        let c = Cache::new(960, 64, 3, CachePolicyKind::Fifo);
        assert_eq!(c.sets(), 4);
    }
}
